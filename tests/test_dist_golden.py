"""Golden log_prob checks against scipy.stats closed forms, plus
jit/vmap/pytree compile-behavior smoke tests for the distribution layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.stats as sps
from jax import random

from repro.core import dist

POSITIVE_X = np.array([0.05, 0.4, 1.0, 2.5, 7.0])
REAL_X = np.array([-2.5, -0.3, 0.0, 0.7, 3.1])
UNIT_X = np.array([0.05, 0.3, 0.5, 0.8, 0.97])

GOLDEN = [
    ("Normal", dist.Normal(0.5, 1.3), sps.norm(0.5, 1.3), REAL_X),
    ("LogNormal", dist.LogNormal(0.2, 0.8),
     sps.lognorm(s=0.8, scale=np.exp(0.2)), POSITIVE_X),
    ("Cauchy", dist.Cauchy(-0.3, 2.0), sps.cauchy(-0.3, 2.0), REAL_X),
    ("StudentT", dist.StudentT(3.5, 0.5, 2.0),
     sps.t(3.5, loc=0.5, scale=2.0), REAL_X),
    ("Gamma", dist.Gamma(2.5, 3.0), sps.gamma(2.5, scale=1 / 3.0),
     POSITIVE_X),
    ("Beta", dist.Beta(2.0, 5.0), sps.beta(2.0, 5.0), UNIT_X),
    ("Exponential", dist.Exponential(1.7), sps.expon(scale=1 / 1.7),
     POSITIVE_X),
    ("HalfNormal", dist.HalfNormal(2.0), sps.halfnorm(scale=2.0),
     POSITIVE_X),
    ("HalfCauchy", dist.HalfCauchy(2.0), sps.halfcauchy(scale=2.0),
     POSITIVE_X),
    ("InverseGamma", dist.InverseGamma(3.0, 2.0),
     sps.invgamma(3.0, scale=2.0), POSITIVE_X),
]


@pytest.mark.parametrize("name,d,ref,xs", GOLDEN,
                         ids=[g[0] for g in GOLDEN])
def test_log_prob_matches_scipy(name, d, ref, xs):
    ours = np.asarray(d.log_prob(jnp.asarray(xs, jnp.float32)))
    np.testing.assert_allclose(ours, ref.logpdf(xs), rtol=2e-5, atol=2e-5)


def test_dirichlet_log_prob_matches_scipy():
    conc = np.array([0.7, 1.5, 3.0])
    x = np.array([0.2, 0.3, 0.5])
    ours = float(dist.Dirichlet(jnp.asarray(conc)).log_prob(jnp.asarray(x)))
    assert abs(ours - sps.dirichlet(conc).logpdf(x)) < 1e-4


def test_mvn_log_prob_matches_scipy():
    cov = np.array([[2.0, 0.4], [0.4, 1.0]])
    loc = np.array([1.0, -0.5])
    x = np.array([[0.0, 0.0], [1.5, -1.0]])
    d = dist.MultivariateNormal(jnp.asarray(loc),
                                covariance_matrix=jnp.asarray(cov))
    np.testing.assert_allclose(
        np.asarray(d.log_prob(jnp.asarray(x))),
        sps.multivariate_normal(loc, cov).logpdf(x), rtol=1e-4)


def test_discrete_log_prob_matches_scipy():
    p = 0.3
    xs = np.array([0, 1, 1, 0])
    ours = np.asarray(dist.Bernoulli(probs=p).log_prob(jnp.asarray(xs)))
    np.testing.assert_allclose(ours, sps.bernoulli(p).logpmf(xs), rtol=1e-5)

    probs = np.array([0.2, 0.5, 0.3])
    ks = np.array([0, 1, 2, 1])
    ours = np.asarray(
        dist.Categorical(probs=jnp.asarray(probs)).log_prob(jnp.asarray(ks)))
    np.testing.assert_allclose(
        ours, sps.rv_discrete(values=(np.arange(3), probs)).logpmf(ks),
        rtol=1e-5, atol=1e-6)


def test_jit_vmap_log_prob_compiles_once():
    """log_prob under jit(vmap(...)) traces exactly once across repeated
    calls with fresh (same-shaped) inputs — no hidden Python state in the
    distribution layer triggers retracing."""
    n_traces = 0

    def lp(loc, scale, x):
        nonlocal n_traces
        n_traces += 1
        return dist.Normal(loc, scale).to_event(1).log_prob(x)

    f = jax.jit(jax.vmap(lp))
    locs = jnp.zeros((4, 3))
    scales = jnp.ones((4, 3))
    xs = random.normal(random.PRNGKey(0), (4, 3))
    first = f(locs, scales, xs)
    second = f(locs + 1.0, scales, xs)  # same shapes: must hit the cache
    assert n_traces == 1
    assert first.shape == second.shape == (4,)


def test_distribution_is_pytree():
    """Distributions cross jit boundaries as pytrees: params are leaves."""
    d = dist.Normal(jnp.arange(3.0), jnp.ones(3))
    leaves = jax.tree_util.tree_leaves(d)
    assert len(leaves) == 2

    @jax.jit
    def through(dd, x):
        return dd.log_prob(x)

    out = through(d, jnp.zeros(3))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(d.log_prob(jnp.zeros(3))), rtol=1e-6)

    # vmap over a batch of distributions
    batched = dist.Normal(jnp.zeros((5, 2)), jnp.ones((5, 2)))
    out = jax.vmap(lambda dd, x: dd.log_prob(x))(batched, jnp.zeros((5, 2)))
    assert out.shape == (5, 2)


def test_expand_draws_iid():
    d = dist.Normal(0.0, 1.0).expand((1000,))
    assert d.batch_shape == (1000,)
    x = d.sample(rng_key=random.PRNGKey(0))
    assert x.shape == (1000,)
    assert float(jnp.std(x)) > 0.5  # iid draws, not a broadcast copy

    e = dist.ExpandedDistribution(dist.Normal(0.0, 1.0), (1000,))
    x = e.sample(rng_key=random.PRNGKey(0))
    assert x.shape == (1000,) and float(jnp.std(x)) > 0.5


# ---------------------------------------------------------------------------
# discrete family: logits-parameterized log_prob goldens + enumerate_support
# ---------------------------------------------------------------------------

@pytest.mark.enum
def test_bernoulli_logits_log_prob_matches_scipy():
    logits = np.array([-3.0, -0.5, 0.0, 1.2, 4.0])
    xs = np.array([0, 1, 1, 0, 1])
    d = dist.Bernoulli(logits=jnp.asarray(logits))
    ref = sps.bernoulli(1.0 / (1.0 + np.exp(-logits)))
    np.testing.assert_allclose(np.asarray(d.log_prob(jnp.asarray(xs))),
                               ref.logpmf(xs), rtol=2e-5, atol=2e-5)


@pytest.mark.enum
def test_bernoulli_extreme_logits_stay_finite():
    """The logits parameterization must not round-trip through probs: at
    +-40 the probability saturates in f32 but the log-density is linear."""
    d = dist.Bernoulli(logits=jnp.array([-40.0, 40.0]))
    lp = np.asarray(d.log_prob(jnp.array([1, 0])))
    np.testing.assert_allclose(lp, [-40.0, -40.0], rtol=1e-6)


@pytest.mark.enum
def test_categorical_logits_log_prob_matches_scipy():
    logits = np.array([0.3, -1.2, 2.0, 0.0])
    probs = np.exp(logits) / np.exp(logits).sum()
    d = dist.Categorical(logits=jnp.asarray(logits))
    xs = np.arange(4)
    ref = sps.multinomial(1, probs)
    expected = np.array([ref.logpmf(np.eye(4)[i]) for i in xs])
    np.testing.assert_allclose(np.asarray(d.log_prob(jnp.asarray(xs))),
                               expected, rtol=2e-5, atol=2e-5)


@pytest.mark.enum
def test_discrete_uniform_log_prob_matches_scipy():
    d = dist.DiscreteUniform(2, 6)
    ref = sps.randint(2, 7)
    xs = np.array([1, 2, 4, 6, 7])
    np.testing.assert_allclose(np.asarray(d.log_prob(jnp.asarray(xs))),
                               ref.logpmf(xs), rtol=2e-5)
    draws = d.sample(rng_key=random.PRNGKey(0), sample_shape=(500,))
    assert draws.dtype == jnp.int32
    assert int(draws.min()) >= 2 and int(draws.max()) <= 6


@pytest.mark.enum
@pytest.mark.parametrize("d,expected_unexpanded,expected_expanded", [
    (dist.Bernoulli(probs=0.3), (2,), (2,)),
    (dist.Bernoulli(logits=jnp.zeros((4,))), (2, 1), (2, 4)),
    (dist.Categorical(probs=jnp.full((5, 3), 1 / 3)), (3, 1), (3, 5)),
    (dist.Categorical(logits=jnp.zeros(6)), (6,), (6,)),
    (dist.DiscreteUniform(1, 4), (4,), (4,)),
], ids=["bern-scalar", "bern-batch", "cat-batch", "cat-logits", "duniform"])
def test_enumerate_support_shapes_and_dtype(d, expected_unexpanded,
                                            expected_expanded):
    sup = d.enumerate_support(expand=False)
    assert sup.shape == expected_unexpanded
    assert jnp.issubdtype(sup.dtype, jnp.integer)
    sup_e = d.enumerate_support(expand=True)
    assert sup_e.shape == expected_expanded
    # every slice along the enum dim is in the support, covering it exactly
    k = sup.shape[0]
    flat = np.unique(np.asarray(sup.reshape(k, -1)[:, 0]))
    assert len(flat) == k
    lp = d.log_prob(sup)
    assert bool(jnp.all(jnp.isfinite(lp)))


@pytest.mark.enum
def test_enumerate_support_values_golden():
    np.testing.assert_array_equal(
        np.asarray(dist.Bernoulli(probs=0.7).enumerate_support()), [0, 1])
    np.testing.assert_array_equal(
        np.asarray(dist.DiscreteUniform(-1, 2).enumerate_support()),
        [-1, 0, 1, 2])
    np.testing.assert_array_equal(
        np.asarray(dist.Categorical(logits=jnp.zeros(3)).enumerate_support()),
        [0, 1, 2])


@pytest.mark.enum
def test_expanded_discrete_keeps_enumerate_support():
    d = dist.Bernoulli(probs=0.3).expand((5,))
    assert d.has_enumerate_support
    assert d.enumerate_support(expand=False).shape == (2, 1)
    assert d.enumerate_support(expand=True).shape == (2, 5)
    assert not dist.Normal(0.0, 1.0).has_enumerate_support
    with pytest.raises(NotImplementedError, match="enumerate_support"):
        dist.Normal(0.0, 1.0).enumerate_support()
