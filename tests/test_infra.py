"""Infrastructure: checkpoint/restore (incl. elastic), data determinism,
optimizers, gradient compression, HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import random

from repro import optim
from repro.data import SyntheticLMData
from repro.distributed import checkpoint as ckpt


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)}}
    ckpt.save(tree, str(tmp_path / "ck"), step=7,
              extra={"data_cursor": 123})
    assert ckpt.latest_step(str(tmp_path / "ck")) == 7
    restored, step, extra = ckpt.restore(tree, str(tmp_path / "ck"))
    assert step == 7 and extra["data_cursor"] == 123
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(
            np.asarray(a, dtype=np.float64), np.asarray(b, dtype=np.float64))


def test_checkpoint_atomic_overwrite(tmp_path):
    tree = {"a": jnp.zeros(3)}
    ckpt.save(tree, str(tmp_path / "ck"), step=1)
    ckpt.save({"a": jnp.ones(3)}, str(tmp_path / "ck"), step=2)
    restored, step, _ = ckpt.restore(tree, str(tmp_path / "ck"))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones(3))


def test_data_determinism_and_sharding():
    d = SyntheticLMData(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    a = d.batch_at(5)
    b = d.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the global batch deterministically
    s0 = d.batch_at(5, dp_rank=0, dp_size=2)
    s1 = d.batch_at(5, dp_rank=1, dp_size=2)
    assert s0["tokens"].shape == (4, 64)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_optimizers_converge_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p - target) ** 2)

    for make in (lambda: optim.adam(0.1),
                 lambda: optim.adamw(0.1, weight_decay=0.0),
                 lambda: optim.adafactor(0.3),
                 lambda: optim.sgd(0.1, momentum=0.9)):
        opt = make()
        p = jnp.zeros(3)
        state = opt.init(p)
        for _ in range(300):
            g = jax.grad(loss)(p)
            upd, state = opt.update(g, state, p)
            p = optim.apply_updates(p, upd)
        assert float(loss(p)) < 1e-2, make


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(1.0)
    g = {"a": jnp.full(4, 10.0)}
    upd, _ = opt.update(g, opt.init(g))
    assert abs(float(optim.global_norm(upd)) - 1.0) < 1e-5


def test_int8_compression_error_feedback():
    from repro.optim.compression import error_feedback_init
    g = {"w": random.normal(random.PRNGKey(0), (256,))}
    ef = error_feedback_init(g)
    out, ef2 = optim.error_feedback_compress(g, ef)
    # compressed+feedback roundtrip preserves the signal on average
    assert out["w"].dtype == g["w"].dtype
    assert float(jnp.abs(out["w"] - g["w"]).mean()) < 0.05
    # residual carries the quantization error for the next step
    assert float(jnp.abs(ef2.residual["w"]).max()) > 0
    # error feedback is unbiased over repeated steps: residual stays bounded
    for _ in range(10):
        out, ef2 = optim.error_feedback_compress(g, ef2)
    assert float(jnp.abs(ef2.residual["w"]).max()) < 0.1


def test_hlo_cost_trip_counts():
    """The analyzer multiplies while bodies by known_trip_count (XLA's own
    cost_analysis does not — the whole reason the module exists)."""
    from repro.launch.hlo_cost import analyze_text

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=8)
        return c

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    res = analyze_text(compiled.as_text())
    expected = 8 * 2 * 128 * 256 * 256
    assert res["flops"] == expected, (res["flops"], expected)
    raw = compiled.cost_analysis()
    if isinstance(raw, list):  # pre-0.4.38 jaxlib wraps it in a 1-list
        raw = raw[0]
    assert raw["flops"] == expected / 8  # XLA counts the body once


def test_warmup_cosine_schedule():
    sched = optim.warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) < 0.2
