"""Effect-handler semantics (paper Table 1 + extended set)."""
import jax
import jax.numpy as jnp
import pytest
from jax import random

import repro.core as pc
from repro.core import dist
from repro.core.handlers import (block, condition, do, mask, replay, scale,
                                 seed, substitute, trace)
from repro.core.infer import log_density


def model(x=None):
    z = pc.sample("z", dist.Normal(0.0, 1.0))
    w = pc.sample("w", dist.Normal(z, 1.0))
    return pc.sample("obs", dist.Normal(w, 1.0), obs=x)


def test_seed_deterministic():
    a = seed(model, random.PRNGKey(0))()
    b = seed(model, random.PRNGKey(0))()
    c = seed(model, random.PRNGKey(1))()
    assert a == b and a != c


def test_seed_splits_per_site():
    tr = trace(seed(model, random.PRNGKey(0))).get_trace()
    assert float(tr["z"]["value"]) != float(tr["w"]["value"])


def test_trace_records_all_sites():
    tr = trace(seed(model, random.PRNGKey(0))).get_trace(jnp.array(1.0))
    assert list(tr) == ["z", "w", "obs"]
    assert tr["obs"]["is_observed"]
    assert not tr["z"]["is_observed"]


def test_condition_observes():
    tr = trace(seed(condition(model, {"z": jnp.array(2.0)}),
                    random.PRNGKey(0))).get_trace()
    assert tr["z"]["is_observed"]
    assert float(tr["z"]["value"]) == 2.0


def test_substitute_stays_latent():
    tr = trace(seed(substitute(model, {"z": jnp.array(2.0)}),
                    random.PRNGKey(0))).get_trace()
    assert not tr["z"]["is_observed"]
    assert float(tr["z"]["value"]) == 2.0


def test_replay():
    guide_tr = trace(seed(model, random.PRNGKey(0))).get_trace()
    tr = trace(seed(replay(model, guide_trace=guide_tr),
                    random.PRNGKey(7))).get_trace()
    assert float(tr["z"]["value"]) == float(guide_tr["z"]["value"])
    assert float(tr["w"]["value"]) == float(guide_tr["w"]["value"])


def test_block():
    tr = trace(block(seed(model, random.PRNGKey(0)),
                     hide=["z"])).get_trace()
    assert "z" not in tr and "w" in tr


def test_do_severs():
    tr = trace(seed(do(model, {"z": jnp.array(5.0)}),
                    random.PRNGKey(0))).get_trace()
    assert "z" not in tr  # hidden from the trace entirely
    # downstream w is centered at the intervened value
    assert abs(float(tr["w"]["value"]) - 5.0) < 5.0


def test_scale_and_mask_in_log_density():
    def m():
        pc.sample("z", dist.Normal(0.0, 1.0), obs=jnp.array(0.0))

    base, _ = log_density(m, (), {}, {})

    def m_scaled():
        with scale(scale=3.0):
            pc.sample("z", dist.Normal(0.0, 1.0), obs=jnp.array(0.0))
    scaled, _ = log_density(m_scaled, (), {}, {})
    assert jnp.allclose(scaled, 3.0 * base)

    def m_masked():
        with mask(mask=jnp.array(False)):
            pc.sample("z", dist.Normal(0.0, 1.0), obs=jnp.array(0.0))
    masked, _ = log_density(m_masked, (), {}, {})
    assert jnp.allclose(masked, 0.0)


def test_plate_expands_and_scales():
    def m():
        with pc.plate("N", 10, subsample_size=5):
            return pc.sample("x", dist.Normal(0.0, 1.0))

    x = seed(m, random.PRNGKey(0))()
    assert x.shape == (5,)
    lp, tr = log_density(seed(m, random.PRNGKey(0)), (), {},
                         {"x": jnp.zeros(5)})
    expected = 2.0 * dist.Normal(0.0, 1.0).log_prob(jnp.zeros(5)).sum()
    assert jnp.allclose(lp, expected)


def test_handlers_compose_with_jit_grad_vmap():
    """The paper's core claim: handlers are invisible to the tracer."""
    def f(key, c):
        tr = trace(seed(substitute(model, {"z": c}),
                        key)).get_trace(jnp.array(0.5))
        return tr["w"]["fn"].log_prob(tr["w"]["value"]).sum()

    keys = random.split(random.PRNGKey(0), 4)
    cs = jnp.arange(4.0)
    out = jax.jit(jax.vmap(jax.grad(f, argnums=1)))(keys, cs)
    assert out.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_unseeded_sample_raises():
    with pytest.raises(ValueError):
        model()


def test_exception_unwinds_stack():
    from repro.core.primitives import stack

    def bad():
        pc.sample("z", dist.Normal(0.0, 1.0))
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        seed(bad, random.PRNGKey(0))()
    assert len(stack()) == 0
