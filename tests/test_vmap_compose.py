"""Paper Sec 3.2 / Fig 1 / App B & D: vmap x handlers composition."""
import jax
import jax.numpy as jnp
from jax import random, vmap

import repro.core as pc
from repro.core import dist
from repro.core.handlers import condition, seed
from repro.core.infer import (SVI, AutoNormal, Predictive, Trace_ELBO,
                              log_likelihood)
from repro import optim


def logistic_regression(x, y=None):
    ndims = x.shape[-1]
    m = pc.sample("m", dist.Normal(0.0, jnp.ones(ndims)).to_event(1))
    b = pc.sample("b", dist.Normal(0.0, 1.0))
    return pc.sample("y", dist.Bernoulli(logits=x @ m + b), obs=y)


def _data(n=80, d=3):
    x = random.normal(random.PRNGKey(0), (n, d))
    y = dist.Bernoulli(logits=x @ jnp.array([1.0, 2.0, 3.0])).sample(
        rng_key=random.PRNGKey(3))
    return x, y


def test_fig1_prior_predictive_vmap():
    x, _ = _data()
    rngs = random.split(random.PRNGKey(2), 10)
    prior_pred = vmap(lambda k: seed(logistic_regression, k)(x))(rngs)
    assert prior_pred.shape == (10, 80)
    assert set(jnp.unique(prior_pred).tolist()) <= {0.0, 1.0}


def test_fig1_posterior_predictive_and_loglik():
    x, y = _data()
    samples = {"m": random.normal(random.PRNGKey(4), (10, 3)),
               "b": random.normal(random.PRNGKey(5), (10,))}
    rngs = random.split(random.PRNGKey(6), 10)

    def predict_fn(rng_key, param):
        return seed(condition(logistic_regression, param), rng_key)(x)

    post_pred = vmap(predict_fn)(rngs, samples)
    assert post_pred.shape == (10, 80)

    ll = log_likelihood(logistic_regression, samples, x, y=y)
    assert ll["y"].shape == (10, 80)
    manual0 = dist.Bernoulli(
        logits=x @ samples["m"][0] + samples["b"][0]).log_prob(y)
    assert jnp.allclose(ll["y"][0], manual0, atol=1e-5)


def test_predictive_utility():
    x, _ = _data()
    samples = {"m": random.normal(random.PRNGKey(4), (7, 3)),
               "b": random.normal(random.PRNGKey(5), (7,))}
    out = Predictive(logistic_regression, posterior_samples=samples)(
        random.PRNGKey(0), x)
    assert out["y"].shape == (7, 80)


def test_vectorized_elbo_appendix_d():
    """App D: multi-particle ELBO via vmap matches the mean of singles."""
    x, y = _data()
    guide = AutoNormal(logistic_regression)
    svi = SVI(logistic_regression, guide, optim.adam(1e-2), Trace_ELBO())
    state = svi.init(random.PRNGKey(0), x, y)
    params = svi.get_params(state)

    elbo = Trace_ELBO()
    keys = random.split(random.PRNGKey(1), 16)
    vec = jnp.mean(vmap(
        lambda k: elbo.loss(k, params, logistic_regression, guide, x, y)
    )(keys))
    seq = jnp.mean(jnp.stack([
        elbo.loss(k, params, logistic_regression, guide, x, y)
        for k in keys]))
    assert jnp.allclose(vec, seq, rtol=1e-4)


def test_multi_particle_elbo_variance_shrinks():
    x, y = _data()
    guide = AutoNormal(logistic_regression)
    svi = SVI(logistic_regression, guide, optim.adam(1e-2), Trace_ELBO())
    params = svi.get_params(svi.init(random.PRNGKey(0), x, y))

    def est(num_particles, key):
        ks = random.split(key, num_particles)
        return jnp.mean(vmap(
            lambda k: Trace_ELBO().loss(k, params, logistic_regression,
                                        guide, x, y))(ks))

    keys = random.split(random.PRNGKey(7), 20)
    v1 = jnp.var(vmap(lambda k: est(1, k))(keys))
    v16 = jnp.var(vmap(lambda k: est(16, k))(keys))
    assert float(v16) < float(v1)


def test_svi_learns_logreg():
    x, y = _data(n=300)
    guide = AutoNormal(logistic_regression)
    svi = SVI(logistic_regression, guide, optim.adam(5e-2), Trace_ELBO())
    state = svi.init(random.PRNGKey(1), x, y)
    step = jax.jit(lambda s: svi.update(s, x, y))
    for _ in range(500):
        state, loss = step(state)
    m = guide.median(svi.get_params(state))["m"]
    assert float(m[2]) > float(m[0])  # recovers coefficient ordering
