"""Reparameterization: the `reparam` handler + strategy library.

Every test drives the real handler stack: strategies issue auxiliary sample
sites that must be seeded/traced/substituted like hand-written ones, and the
whole composition must be invisible to jit/vmap/grad (paper Sec 2).
"""
import jax
import jax.numpy as jnp
import pytest
from jax import random

import repro.core as pc
from repro.core import dist
from repro.core.handlers import reparam, seed, substitute, trace
from repro.core.infer import log_density
from repro.core.reparam import LocScaleReparam, Reparam, TransformReparam


def funnel():
    mu = pc.sample("mu", dist.Normal(0.0, 3.0))
    tau = pc.sample("tau", dist.HalfNormal(3.0))
    with pc.plate("J", 5):
        theta = pc.sample("theta", dist.Normal(mu, tau))
    return theta


NC = {"theta": LocScaleReparam(0.0)}


def test_locscale_sites_and_shapes():
    tr = trace(seed(reparam(funnel, config=NC), random.PRNGKey(0))).get_trace()
    assert tr["theta_decentered"]["type"] == "sample"
    assert not tr["theta_decentered"]["is_observed"]
    assert tr["theta"]["type"] == "deterministic"
    assert tr["theta"]["value"].shape == (5,)
    # deterministic identity: theta == mu + tau * eps for centered=0
    expected = (tr["mu"]["value"]
                + tr["tau"]["value"] * tr["theta_decentered"]["value"])
    assert jnp.allclose(tr["theta"]["value"], expected, atol=1e-6)


def test_locscale_density_invariance():
    """p(mu, tau, theta) == p(mu, tau, eps) |d theta / d eps|^-1 ... for the
    loc-scale family the change of variables is exact: the non-centered joint
    at eps must equal the centered joint at theta = mu + tau*eps minus the
    log-Jacobian J = 5 * log(tau)."""
    mu, tau, eps = jnp.array(0.7), jnp.array(1.3), jnp.arange(5.0) / 3 - 0.5
    lp_nc, tr = log_density(
        seed(reparam(funnel, config=NC), random.PRNGKey(0)), (), {},
        {"mu": mu, "tau": tau, "theta_decentered": eps})
    theta = tr["theta"]["value"]
    lp_c, _ = log_density(seed(funnel, random.PRNGKey(0)), (), {},
                          {"mu": mu, "tau": tau, "theta": theta})
    assert jnp.allclose(lp_nc, lp_c + 5 * jnp.log(tau), atol=1e-4)


def test_locscale_partial_centering():
    """centered=0.5 interpolates; centered=1.0 is the identity."""
    tr = trace(seed(reparam(funnel, config={"theta": LocScaleReparam(0.5)}),
                    random.PRNGKey(0))).get_trace()
    mu, tau = tr["mu"]["value"], tr["tau"]["value"]
    dec = tr["theta_decentered"]["value"]
    expected = mu + jnp.sqrt(tau) * (dec - 0.5 * mu)
    assert jnp.allclose(tr["theta"]["value"], expected, atol=1e-5)

    tr1 = trace(seed(reparam(funnel, config={"theta": LocScaleReparam(1.0)}),
                     random.PRNGKey(0))).get_trace()
    assert "theta_decentered" not in tr1
    assert tr1["theta"]["type"] == "sample"


def test_transform_reparam():
    def model():
        return pc.sample("z", dist.TransformedDistribution(
            dist.Normal(0.0, 1.0), dist.AffineTransform(3.0, 2.0)))

    tr = trace(seed(reparam(model, config={"z": TransformReparam()}),
                    random.PRNGKey(0))).get_trace()
    assert tr["z"]["type"] == "deterministic"
    assert jnp.allclose(tr["z"]["value"],
                        3.0 + 2.0 * tr["z_base"]["value"], atol=1e-6)


def test_transformed_distribution_log_prob_matches_lognormal():
    td = dist.TransformedDistribution(dist.Normal(0.5, 1.3),
                                      dist.transforms.ExpTransform())
    v = jnp.array([0.3, 1.0, 2.5])
    assert jnp.allclose(td.log_prob(v), dist.LogNormal(0.5, 1.3).log_prob(v),
                        atol=1e-5)
    x = td.sample(rng_key=random.PRNGKey(0), sample_shape=(100,))
    assert x.shape == (100,) and bool(jnp.all(x > 0))


def test_reparam_observed_site_raises():
    def model(y=None):
        pc.sample("y", dist.Normal(0.0, 1.0), obs=y)

    with pytest.raises(ValueError, match="observed"):
        seed(reparam(model, config={"y": LocScaleReparam(0.0)}),
             random.PRNGKey(0))(jnp.array(1.0))


def test_reparam_callable_config():
    config = (lambda msg: LocScaleReparam(0.0)
              if msg["name"] == "theta" else None)
    tr = trace(seed(reparam(funnel, config=config),
                    random.PRNGKey(0))).get_trace()
    assert "theta_decentered" in tr and tr["mu"]["type"] == "sample"


def test_reparam_composes_with_jit_vmap_grad():
    """New-handler contract: reparam'd densities differentiate and batch."""
    def lp(key, mu):
        return log_density(
            seed(reparam(funnel, config=NC), key), (), {},
            {"mu": mu, "tau": jnp.array(1.0),
             "theta_decentered": jnp.zeros(5)})[0]

    keys = random.split(random.PRNGKey(0), 3)
    mus = jnp.arange(3.0)
    out = jax.jit(jax.vmap(jax.grad(lp, argnums=1)))(keys, mus)
    assert out.shape == (3,)
    # d/dmu [ log N(mu; 0, 3) ] = -mu/9 (theta term drops out at eps=0)
    assert jnp.allclose(out, -mus / 9.0, atol=1e-5)


def test_reparam_substitution_of_auxiliary():
    """Auxiliary sites are first-class: substituting them pins the original
    site's deterministic value (the mechanism Predictive relies on)."""
    m = substitute(seed(reparam(funnel, config=NC), random.PRNGKey(0)),
                   data={"mu": jnp.array(2.0), "tau": jnp.array(1.0),
                         "theta_decentered": jnp.zeros(5)})
    tr = trace(m).get_trace()
    assert jnp.allclose(tr["theta"]["value"], jnp.full(5, 2.0), atol=1e-6)


def test_custom_strategy_swap_fn():
    """A strategy may return (new_fn, None) to merely swap the distribution."""
    class Widen(Reparam):
        def __call__(self, name, fn, obs):
            return dist.Normal(0.0, 10.0), None

    tr = trace(seed(reparam(lambda: pc.sample("z", dist.Normal(0.0, 1.0)),
                            config={"z": Widen()}),
                    random.PRNGKey(0))).get_trace()
    assert float(tr["z"]["fn"].scale) == 10.0


def test_eight_schools_noncentered_converges_where_centered_does_not():
    """ISSUE 3 acceptance: at short-chain settings the centered funnel fails
    the R-hat 1.05 cut while LocScaleReparam's non-centered form passes on
    every site — both through the same jit-compiled vectorized executor."""
    from repro.core.infer import MCMC, NUTS, gelman_rubin

    y = jnp.array([28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0])
    sigma = jnp.array([15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0])

    def eight_schools(y=None):
        mu = pc.sample("mu", dist.Normal(0.0, 5.0))
        tau = pc.sample("tau", dist.HalfCauchy(5.0))
        with pc.plate("J", 8):
            theta = pc.sample("theta", dist.Normal(mu, tau))
            pc.sample("obs", dist.Normal(theta, sigma), obs=y)

    def worst_rhat(model):
        mcmc = MCMC(NUTS(model), num_warmup=150, num_samples=200,
                    num_chains=4)
        mcmc.run(random.PRNGKey(0), y=y)
        return max(float(jnp.max(jnp.asarray(gelman_rubin(v))))
                   for v in mcmc.get_samples(group_by_chain=True).values())

    rhat_c = worst_rhat(eight_schools)
    rhat_nc = worst_rhat(reparam(eight_schools,
                                 config={"theta": LocScaleReparam(0.0)}))
    assert rhat_nc < 1.05, f"non-centered failed to converge: {rhat_nc}"
    assert rhat_c > rhat_nc, (
        f"reparameterization did not improve mixing ({rhat_c} vs {rhat_nc})")
    assert rhat_c >= 1.05, (
        f"centered unexpectedly converged at short-chain settings: {rhat_c}")


def test_callable_config_does_not_recurse_on_auxiliary_sites():
    """Regression: a blanket callable config must not reparameterize the
    auxiliary sites the strategies themselves emit (unbounded recursion)."""
    def model():
        return pc.sample("theta", dist.Normal(1.0, 2.0))

    blanket = reparam(model, config=lambda msg: LocScaleReparam(0.0))
    tr = trace(seed(blanket, random.PRNGKey(0))).get_trace()
    assert set(tr) == {"theta_decentered", "theta"}
    assert tr["theta_decentered"]["infer"]["reparam_auxiliary"]


def test_transformed_distribution_broadcasts_batched_transform_params():
    """Regression: batched AffineTransform params must yield independent base
    draws per component, not one shared epsilon."""
    locs, scales = jnp.zeros(8), jnp.arange(1.0, 9.0)
    td = dist.TransformedDistribution(dist.Normal(0.0, 1.0),
                                      dist.AffineTransform(locs, scales))
    assert td.batch_shape == (8,)
    x = td.sample(rng_key=random.PRNGKey(0))
    assert x.shape == (8,)
    eps = x / scales
    assert len({round(float(e), 4) for e in eps}) == 8  # independent draws
    assert jnp.allclose(td.log_prob(x),
                        dist.Normal(locs, scales).log_prob(x), atol=1e-5)

    # TransformReparam inherits the corrected shape for the base site
    def model():
        return pc.sample("z", dist.TransformedDistribution(
            dist.Normal(0.0, 1.0), dist.AffineTransform(locs, scales)))

    tr = trace(seed(reparam(model, config={"z": TransformReparam()}),
                    random.PRNGKey(0))).get_trace()
    assert tr["z_base"]["value"].shape == (8,)
    base = tr["z_base"]["value"]
    assert len({round(float(b), 4) for b in base}) == 8


def test_substituted_value_into_reparamed_site_raises():
    """Regression: an inner substitute pinning the original site must fail
    loudly — the strategy would otherwise sample fresh auxiliaries and
    silently evaluate elsewhere."""
    from repro.core.handlers import reparam as reparam_h

    inner = substitute(funnel, {"theta": jnp.zeros(5)})
    with pytest.raises(ValueError, match="configured for reparameterization"):
        seed(reparam_h(inner, config=NC), random.PRNGKey(0))()


def test_transformed_distribution_unrepresentable_support_raises():
    """Regression: a constraining transform followed by an affine has a
    support we cannot express — fail at setup, not with NaNs mid-chain."""
    td = dist.TransformedDistribution(
        dist.Normal(0.0, 1.0),
        [dist.transforms.ExpTransform(), dist.AffineTransform(1.0, 1.0)])
    with pytest.raises(NotImplementedError, match="constraining non-final"):
        td.support
    # affine-then-constraining is fine: support is the final codomain
    ok = dist.TransformedDistribution(
        dist.Normal(0.0, 1.0),
        [dist.AffineTransform(1.0, 2.0), dist.transforms.ExpTransform()])
    assert ok.support is ok.transforms[-1].codomain


def test_transformed_distribution_constrained_base_support_raises():
    """Regression: a constrained base (e.g. Exponential) pushed through a
    real-codomain transform must not report support=real (biject_to would
    hand inference an identity bijection and log_prob diverges off-support)."""
    td = dist.TransformedDistribution(dist.Exponential(1.0),
                                      dist.AffineTransform(0.0, 1.0))
    with pytest.raises(NotImplementedError, match="not representable"):
        td.support


def test_transformed_distribution_log_prob_broadcasts_scalar_value():
    """Regression: a scalar value against batched transform params must score
    per-component, not sum the Jacobians across the batch."""
    td = dist.TransformedDistribution(
        dist.Normal(0.0, 1.0),
        dist.AffineTransform(jnp.array([0.0, 1.0, 2.0]),
                             jnp.array([1.0, 2.0, 3.0])))
    got = td.log_prob(jnp.array(1.5))
    want = dist.Normal(jnp.array([0.0, 1.0, 2.0]),
                       jnp.array([1.0, 2.0, 3.0])).log_prob(1.5)
    assert got.shape == (3,)
    assert jnp.allclose(got, want, atol=1e-5)
