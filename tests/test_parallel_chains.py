"""chain_method="parallel": the vmap'd chain program with the chain axis
sharded over devices (subprocess with 8 virtual devices)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax import random
import repro.core as pc
from repro.core import dist
from repro.core.infer import MCMC, NUTS, gelman_rubin

def model():
    x = pc.sample("x", dist.Normal(1.0, 2.0))

mcmc = MCMC(NUTS(model), num_warmup=200, num_samples=200, num_chains=8,
            chain_method="parallel")
mcmc.run(random.PRNGKey(0))
x = mcmc.get_samples(group_by_chain=True)["x"]
assert x.shape == (8, 200)
# chains actually landed on distinct devices
devs = {d.id for d in mcmc.last_state.z.sharding.device_set}
flat = mcmc.get_samples()["x"]
print(json.dumps({
    "n_devices": len(devs),
    "mean": float(flat.mean()),
    "std": float(flat.std()),
    "rhat": float(gelman_rubin(x)),
}))
"""


@pytest.mark.slow
def test_parallel_chains_shard_over_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["n_devices"] == 8, r
    assert abs(r["mean"] - 1.0) < 0.3, r
    assert abs(r["std"] - 2.0) < 0.4, r
    assert r["rhat"] < 1.1, r
