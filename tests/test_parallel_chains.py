"""chain_method="parallel": the vmap'd chain program with the chain axis
sharded over devices (subprocess with 8 virtual devices)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax import random
import repro.core as pc
from repro.core import dist
from repro.core.infer import MCMC, NUTS, gelman_rubin

def model():
    x = pc.sample("x", dist.Normal(1.0, 2.0))

mcmc = MCMC(NUTS(model), num_warmup=200, num_samples=200, num_chains=8,
            chain_method="parallel")
mcmc.run(random.PRNGKey(0))
x = mcmc.get_samples(group_by_chain=True)["x"]
assert x.shape == (8, 200)
# chains actually landed on distinct devices
devs = {d.id for d in mcmc.last_state.z.sharding.device_set}
flat = mcmc.get_samples()["x"]
print(json.dumps({
    "n_devices": len(devs),
    "mean": float(flat.mean()),
    "std": float(flat.std()),
    "rhat": float(gelman_rubin(x)),
}))
"""


def test_checkpoint_kill_resume_bit_identical(tmp_path):
    """checkpoint → kill → resume: the resumed multi-chain run must finish
    with bit-identical samples vs an uninterrupted run (chunk boundaries
    are a pure function of the iteration count)."""
    import numpy as np
    from jax import random

    import repro.core as pc
    from repro.core import dist
    from repro.core.infer import MCMC, NUTS
    from repro.distributed import checkpoint as ckpt

    def model():
        pc.sample("x", dist.Normal(1.0, 2.0))

    def make():
        return MCMC(NUTS(model), num_warmup=60, num_samples=80,
                    num_chains=4, chain_method="vectorized")

    # uninterrupted reference (no checkpointing at all)
    ref = make()
    ref.run(random.PRNGKey(9))
    expected = np.asarray(ref.get_samples(group_by_chain=True)["x"])

    # checkpointed run, killed mid-sampling: the kill lands between a chunk's
    # samples write and its state write, leaving an orphaned samples dir the
    # resume path must deterministically rewrite
    ckdir = str(tmp_path / "chains")
    state_dir = os.path.join(ckdir, "state")
    real_save, calls = ckpt.save, {"n": 0}

    def killing_save(tree, directory, **kw):
        real_save(tree, directory, **kw)
        calls["n"] += 1
        if calls["n"] == 6:
            raise KeyboardInterrupt("preempted")

    ckpt.save = killing_save
    try:
        with pytest.raises(KeyboardInterrupt):
            make().run(random.PRNGKey(9), checkpoint_every=25,
                       checkpoint_dir=ckdir)
    finally:
        ckpt.save = real_save

    step = ckpt.latest_step(state_dir)
    assert step is not None and 0 < step < 140, step

    # relaunch with resume=True: continues from latest_step to the end
    resumed = make()
    resumed.run(random.PRNGKey(9), checkpoint_every=25, checkpoint_dir=ckdir,
                resume=True)
    got = np.asarray(resumed.get_samples(group_by_chain=True)["x"])
    np.testing.assert_array_equal(got, expected)
    # the final checkpoint on disk covers the whole run and is restorable
    assert ckpt.latest_step(state_dir) == 140
    restored, _, _ = ckpt.restore(
        {"chain_state": resumed.last_state}, state_dir)
    np.testing.assert_array_equal(
        np.asarray(restored["chain_state"].z),
        np.asarray(resumed.last_state.z))
    # sample chunks on disk are append-only and cover the sampling phase
    chunks = sorted(n for n in os.listdir(ckdir) if n.startswith("samples_"))
    assert chunks[0] == "samples_000060_000085"
    assert chunks[-1] == "samples_000135_000140"


def test_resume_with_different_checkpoint_every(tmp_path):
    """A resume may change checkpoint_every: orphaned chunk dirs from the
    interrupted chunking are cleaned up, the finished checkpoint stays
    restorable, and samples still match the uninterrupted run bitwise."""
    import numpy as np
    from jax import random

    import repro.core as pc
    from repro.core import dist
    from repro.core.infer import MCMC, NUTS
    from repro.distributed import checkpoint as ckpt

    def model():
        pc.sample("x", dist.Normal(0.0, 1.0))

    def make():
        return MCMC(NUTS(model), num_warmup=40, num_samples=60, num_chains=2)

    ref = make()
    ref.run(random.PRNGKey(4))
    expected = np.asarray(ref.get_samples(group_by_chain=True)["x"])

    ckdir = str(tmp_path / "ck")
    real_save, calls = ckpt.save, {"n": 0}

    def killing_save(tree, directory, **kw):
        real_save(tree, directory, **kw)
        calls["n"] += 1
        if calls["n"] == 4:   # after samples_000040_000055 lands, state at 40
            raise KeyboardInterrupt

    ckpt.save = killing_save
    try:
        with pytest.raises(KeyboardInterrupt):
            make().run(random.PRNGKey(4), checkpoint_every=15,
                       checkpoint_dir=ckdir)
    finally:
        ckpt.save = real_save

    # resume with a coarser chunking: must clean the orphaned 15-wide chunk
    resumed = make()
    resumed.run(random.PRNGKey(4), checkpoint_every=40, checkpoint_dir=ckdir,
                resume=True)
    np.testing.assert_array_equal(
        np.asarray(resumed.get_samples(group_by_chain=True)["x"]), expected)

    # the finished checkpoint restores cleanly (the rebuild-from-disk flow)
    again = make()
    again.run(random.PRNGKey(4), checkpoint_dir=ckdir, resume=True)
    np.testing.assert_array_equal(
        np.asarray(again.get_samples(group_by_chain=True)["x"]), expected)


def test_resume_with_mismatched_run_shape_raises(tmp_path):
    """A checkpoint written by a different (warmup, samples, chains) run
    must be rejected, not silently reinterpreted."""
    from jax import random

    import repro.core as pc
    from repro.core import dist
    from repro.core.infer import MCMC, NUTS

    def model():
        pc.sample("x", dist.Normal(0.0, 1.0))

    d = str(tmp_path / "ck")
    MCMC(NUTS(model), num_warmup=20, num_samples=30, num_chains=2).run(
        random.PRNGKey(0), checkpoint_every=25, checkpoint_dir=d)
    bad = MCMC(NUTS(model), num_warmup=20, num_samples=50, num_chains=2)
    with pytest.raises(ValueError, match="num_samples"):
        bad.run(random.PRNGKey(0), checkpoint_dir=d, resume=True)


@pytest.mark.slow
def test_parallel_chains_shard_over_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["n_devices"] == 8, r
    assert abs(r["mean"] - 1.0) < 0.3, r
    assert abs(r["std"] - 2.0) < 0.4, r
    assert r["rhat"] < 1.1, r
