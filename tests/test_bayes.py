"""core.bayes: the paper's handlers at weight scale (lift, log_prior)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import random

import repro.core as pc
from repro.core import bayes, dist
from repro.core.handlers import seed, trace
from repro.core.infer import MCMC, NUTS
from repro.core.primitives import param


def test_log_prior_matches_manual():
    params = {"w": jnp.array([[1.0, -2.0], [0.5, 0.0]]),
              "scale": jnp.array([1.0, 2.0])}       # ndim<2: excluded
    sigma = 3.0
    lp = bayes.log_prior(params, sigma=sigma)
    manual = dist.Normal(0.0, sigma).log_prob(params["w"]).sum()
    assert jnp.allclose(lp, manual, rtol=1e-6)


def test_log_prior_grad_is_weight_decay():
    """d(-log p)/dw = w / sigma^2 — MAP == decoupled weight decay."""
    w = {"w": jnp.array([[2.0, -4.0]])}
    g = jax.grad(lambda p: -bayes.log_prior(p, sigma=2.0))(w)
    np.testing.assert_allclose(np.asarray(g["w"]),
                               np.asarray(w["w"]) / 4.0, rtol=1e-6)


def test_log_prior_inside_jit_grad():
    w = {"a": random.normal(random.PRNGKey(0), (8, 8))}
    f = jax.jit(jax.grad(lambda p: -bayes.log_prior(p, sigma=1.0)))
    g = f(w)
    np.testing.assert_allclose(np.asarray(g["a"]), np.asarray(w["a"]),
                               rtol=1e-5)


def _model(x, y=None):
    w = param("w", shape=(x.shape[-1],),
              init_fn=lambda k, s, d: 0.1 * random.normal(k, s))
    pc.sample("y", dist.Normal(x @ w, 0.5).to_event(1), obs=y)


def test_lift_converts_param_to_sample():
    x = random.normal(random.PRNGKey(0), (20, 3))
    lifted = bayes.lift(_model, prior_fn=lambda m: dist.Normal(0.0, 1.0)
                        .expand(m["kwargs"]["shape"]).to_event(1))
    tr = trace(seed(lifted, random.PRNGKey(1))).get_trace(x)
    assert tr["w"]["type"] == "sample"
    assert not tr["w"]["is_observed"]
    assert tr["w"]["value"].shape == (3,)


def test_lifted_model_nuts_recovers_weights():
    """Full circle: a `param`-declared model becomes Bayesian via lift and
    NUTS recovers the generating weights."""
    true_w = jnp.array([1.0, -1.0])
    x = random.normal(random.PRNGKey(0), (100, 2))
    y = x @ true_w + 0.1 * random.normal(random.PRNGKey(1), (100,))
    lifted = bayes.lift(_model)
    mcmc = MCMC(NUTS(lifted), num_warmup=200, num_samples=200)
    mcmc.run(random.PRNGKey(2), x, y=y)
    w_post = mcmc.get_samples()["w"]
    np.testing.assert_allclose(np.asarray(w_post.mean(0)),
                               np.asarray(true_w), atol=0.15)
