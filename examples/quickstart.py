"""Quickstart: the paper's Figure 1 / Appendix B end to end, on the
pure-functional kernel API.

Logistic regression -> iterative-NUTS inference (a ``KernelSetup`` whose
``init``/``sample`` are pure functions, so the whole chain is one explicit
``lax.scan``) -> vmap'd prior predictive, posterior predictive, and
log-likelihood, composing `seed`/`trace`/`condition` handlers with `vmap`
(the paper's core demonstration).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
from jax import lax, random, vmap
from jax.scipy.special import logsumexp

import repro.core as pc
from repro.core import dist
from repro.core.handlers import condition, seed, trace
from repro.core.infer import init_state, nuts_setup, print_summary, sample


def logistic_regression(x, y=None):
    ndims = x.shape[-1]
    m = pc.sample("m", dist.Normal(0.0, jnp.ones(ndims)).to_event(1))
    b = pc.sample("b", dist.Normal(0.0, 1.0))
    return pc.sample("y", dist.Bernoulli(logits=x @ m + b), obs=y)


def predict_fn(rng_key, param, x):
    conditioned = condition(logistic_regression, param)
    return seed(conditioned, rng_key)(x)


def loglik_fn(rng_key, params, x, y):
    tr = trace(lambda *a: predict_fn(rng_key, params, x)).get_trace()
    obs_node = tr["y"]
    return dist.Bernoulli(logits=x @ params["m"] + params["b"]).log_prob(y)


def main():
    # generate random data (paper App B)
    true_coefs = jnp.array([1.0, 2.0, 3.0])
    x = random.normal(random.PRNGKey(0), (100, 3))
    y = dist.Bernoulli(logits=x @ true_coefs).sample(
        rng_key=random.PRNGKey(3))

    # inference on the functional kernel API: `setup` is the static half
    # (model trace, potential closure, adaptation schedule); the chain state
    # is an explicit pytree and init/sample are pure, so warmup + sampling
    # below is one jit'd lax.scan — and batching chains is just vmap.
    num_warmup, num_samples = 500, 500
    setup = nuts_setup(random.PRNGKey(1), num_warmup,
                       model=logistic_regression, model_args=(x,),
                       model_kwargs={"y": y})

    @jax.jit
    def run_chain(key):
        state = init_state(setup, key)
        state = lax.scan(lambda s, _: (sample(setup, s), None), state,
                         None, length=num_warmup)[0]

        def body(s, _):
            s = sample(setup, s)
            return s, s.z

        _, zs = lax.scan(body, state, None, length=num_samples)
        return zs

    zs = run_chain(random.PRNGKey(1))                    # (samples, D) flat
    samples = vmap(setup.constrain_fn)(zs)               # site-keyed dict
    print_summary(jax.tree_util.tree_map(lambda v: v[None], samples))

    # vectorized prediction & log likelihood (paper Fig 1c)
    rngs_sim = random.split(random.PRNGKey(2), num_samples)
    rngs_pred = random.split(random.PRNGKey(3), num_samples)
    prior_predictive = vmap(
        lambda k: seed(logistic_regression, k)(x))(rngs_sim)
    posterior_predictive = vmap(
        lambda k, p: predict_fn(k, p, x))(rngs_pred, samples)
    log_likelihood = vmap(
        lambda k, p: loglik_fn(k, p, x, y).sum())(rngs_pred, samples)
    exp_ll = logsumexp(log_likelihood) - jnp.log(num_samples)

    print(f"prior predictive mean:     {prior_predictive.mean():.3f}")
    print(f"posterior predictive mean: {posterior_predictive.mean():.3f}")
    print(f"observed mean:             {y.mean():.3f}")
    print(f"expected log likelihood:   {exp_ll:.2f}")
    m = samples["m"].mean(0)
    print(f"posterior mean coefs:      {m} (true {true_coefs})")
    assert abs(float(posterior_predictive.mean()) - float(y.mean())) < 0.1


if __name__ == "__main__":
    main()
