"""Instrumented inference: the ``repro.obs`` telemetry subsystem end to end.

One NUTS run with a :class:`~repro.obs.Telemetry` attached writes three
artifacts into the output directory — an ``events.jsonl`` stream (run
lifecycle, per-chunk metric summaries, phase spans), a ``run_manifest.json``
(environment, chunk schedule, timings, final diagnostics), and the in-memory
metrics series (``step_size``, ``accept_prob``, ``diverging``, ... as
``(chains, draws)`` arrays).  The sample stream is bit-identical with
telemetry on or off: metrics ride the chunked scan's collect outputs, never
its carry, and come off-device once per compiled chunk.

    PYTHONPATH=src python examples/telemetry_logreg.py [out_dir]

Validate the artifacts against their checked-in schemas afterwards::

    PYTHONPATH=src python -m repro.obs.validate out_dir/events.jsonl
    PYTHONPATH=src python -m repro.obs.validate out_dir/run_manifest.json
"""
import sys

import jax.numpy as jnp
from jax import random

import repro.core as pc
from repro import obs
from repro.core import dist
from repro.core.infer import MCMC, NUTS, print_summary


def logistic_regression(x, y=None):
    ndims = x.shape[-1]
    m = pc.sample("m", dist.Normal(0.0, jnp.ones(ndims)).to_event(1))
    b = pc.sample("b", dist.Normal(0.0, 1.0))
    return pc.sample("y", dist.Bernoulli(logits=x @ m + b), obs=y)


def main(out_dir="telemetry_run"):
    true_coefs = jnp.array([1.0, 2.0, 3.0])
    x = random.normal(random.PRNGKey(0), (200, 3))
    y = dist.Bernoulli(logits=x @ true_coefs).sample(
        rng_key=random.PRNGKey(3))

    tele = obs.Telemetry(dir=out_dir)
    mcmc = MCMC(NUTS(logistic_regression), num_warmup=300, num_samples=300,
                num_chains=4, telemetry=tele)
    mcmc.run(random.PRNGKey(1), x, y=y)
    print_summary(mcmc.get_samples(group_by_chain=True))

    series = tele.buffer.series("sample")
    print(f"metrics streams: {sorted(series)}")
    print(f"accept_prob series shape: {series['accept_prob'].shape} "
          f"(chains, draws), mean {series['accept_prob'].mean():.3f}")
    for rec in tele.spans:
        if rec.name in ("setup", "init", "warmup_chunk", "sample_chunk"):
            print(f"span {rec.name:>13s}: {rec.duration_s * 1e3:8.1f} ms"
                  + ("  [cold]" if rec.attr("program_cold") else ""))
    print(f"artifacts in {out_dir}/: events.jsonl, {obs.MANIFEST_NAME}")


if __name__ == "__main__":
    main(*sys.argv[1:2])
