"""Cheap high-volume inference: batched MALA and random-walk Metropolis.

Both kernels implement the batch-aware ``cross_chain`` contract — the whole
(chains, dim) ensemble moves through one chain-batched proposal kernel
(:func:`repro.kernels.ops.mala_step`) per draw, and warmup pools the step
size (cross-chain dual averaging) and the diagonal preconditioner (pooled
Welford) across every chain, exactly like ChEES-HMC.  At one gradient per
draw (MALA) or zero (RWM), raw draws/sec beat trajectory-based samplers on
well-conditioned posteriors — the serving-scale regime: many chains, short
runs.

    PYTHONPATH=src python examples/mala_logreg.py
"""
import jax.numpy as jnp
from jax import random

import repro.core as pc
from repro.core import dist
from repro.core.infer import MALA, MCMC, RWM, print_summary


def logistic_regression(x, y=None):
    """The quickstart model, marked for the fused GLM potential: value and
    gradient of the Bernoulli-logit likelihood come from one pass over x."""
    ndims = x.shape[-1]
    m = pc.sample("m", dist.Normal(0.0, jnp.ones(ndims)).to_event(1))
    b = pc.sample("b", dist.Normal(0.0, 1.0))
    return pc.sample("y", dist.Bernoulli(logits=x @ m + b), obs=y,
                     infer={"potential": "glm"})


def location_scale(y=None, n=80):
    """A tiny location-scale model for the gradient-free RWM kernel."""
    mu = pc.sample("mu", dist.Normal(0.0, 5.0))
    sigma = pc.sample("sigma", dist.LogNormal(0.0, 1.0))
    with pc.plate("data", n if y is None else y.shape[0]):
        return pc.sample("y", dist.Normal(mu, sigma), obs=y)


def main():
    true_coefs = jnp.array([1.0, 2.0, 3.0])
    x = random.normal(random.PRNGKey(0), (200, 3))
    y = dist.Bernoulli(logits=x @ true_coefs).sample(
        rng_key=random.PRNGKey(3))

    # 64 chains in lockstep: one (64, 4) proposal per draw, pooled warmup
    mcmc = MCMC(MALA(logistic_regression), num_warmup=1000,
                num_samples=1000, num_chains=64)
    mcmc.run(random.PRNGKey(1), x, y=y)
    samples = mcmc.get_samples()
    print("MALA posterior (64 chains x 1000 draws):")
    print_summary(mcmc.get_samples(group_by_chain=True))
    m = samples["m"].mean(0)
    print(f"posterior mean coefs: {m} (true {true_coefs})")

    y_obs = 1.5 + 0.8 * random.normal(random.PRNGKey(4), (80,))
    mcmc = MCMC(RWM(location_scale), num_warmup=1000, num_samples=1000,
                num_chains=64)
    mcmc.run(random.PRNGKey(2), y=y_obs)
    print("RWM posterior (zero gradients per draw):")
    print_summary(mcmc.get_samples(group_by_chain=True))


if __name__ == "__main__":
    main()
