"""Eight schools: centered vs non-centered parameterization via `reparam`.

The classic hierarchical meta-analysis (Rubin 1981; Gelman et al. BDA) is the
textbook funnel: with only 8 groups the posterior over the group-level scale
``tau`` concentrates near zero, and in the *centered* parameterization
``theta_j ~ Normal(mu, tau)`` NUTS must shrink its step size to enter the
funnel neck, so chains mix poorly.  Wrapping the unchanged model in

    reparam(eight_schools, config={"theta": LocScaleReparam(0.0)})

rewrites the site on the fly into ``theta_decentered ~ Normal(0, 1)`` plus the
deterministic ``theta = mu + tau * theta_decentered`` — same joint density,
benign geometry — demonstrating the paper's claim that inference-motivated
model surgery is a *handler*, not a model rewrite.  Both variants run through
the identical jit-compiled NUTS executor (one compiled program per variant:
warmup + sampling is a single chunked ``lax.scan`` over vmapped chains).

    PYTHONPATH=src python examples/eight_schools.py
    PYTHONPATH=src python examples/eight_schools.py --kernel chees

``--kernel chees`` swaps the No-U-Turn sampler for the ChEES-HMC ensemble
kernel (docs/ensemble.md): same model, same jit'd chunked executor, but the
8 chains run fixed-length Halton-jittered trajectories in lockstep and the
warmup pools step-size/mass statistics across the batch.  The posterior
summaries match NUTS within Monte-Carlo error — which the script asserts.
"""
import argparse

import jax.numpy as jnp
from jax import random

import repro.core as pc
from repro.core import dist
from repro.core.handlers import reparam
from repro.core.infer import (ChEES, MCMC, NUTS, Predictive,
                              effective_sample_size, gelman_rubin)
from repro.core.reparam import LocScaleReparam

J = 8
y = jnp.array([28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0])
sigma = jnp.array([15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0])

NUM_WARMUP, NUM_SAMPLES, NUM_CHAINS = 150, 200, 4


def eight_schools(y=None):
    mu = pc.sample("mu", dist.Normal(0.0, 5.0))
    tau = pc.sample("tau", dist.HalfCauchy(5.0))
    with pc.plate("J", J):
        theta = pc.sample("theta", dist.Normal(mu, tau))
        pc.sample("obs", dist.Normal(theta, sigma), obs=y)
    return theta


def make_kernel(model, kind="nuts"):
    return ChEES(model) if kind == "chees" else NUTS(model)


def run(model, kind="nuts"):
    mcmc = MCMC(make_kernel(model, kind), num_warmup=NUM_WARMUP,
                num_samples=NUM_SAMPLES, num_chains=NUM_CHAINS)
    mcmc.run(random.PRNGKey(0), y=y)
    samples = mcmc.get_samples(group_by_chain=True)
    diagnostics = {
        name: (float(jnp.max(jnp.asarray(gelman_rubin(v)))),
               float(jnp.min(jnp.asarray(effective_sample_size(v)))))
        for name, v in samples.items()
    }
    return mcmc, diagnostics


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--kernel", choices=["nuts", "chees"],
                        default="nuts",
                        help="chees = lockstep ensemble trajectories with "
                             "cross-chain adaptation (docs/ensemble.md)")
    kind = parser.parse_args().kernel

    print(f"{kind.upper()}, {NUM_CHAINS} chains x ({NUM_WARMUP} warmup + "
          f"{NUM_SAMPLES} samples), one jit-compiled executor per variant\n")

    _, diag_c = run(eight_schools, kind)
    noncentered = reparam(eight_schools,
                          config={"theta": LocScaleReparam(0.0)})
    mcmc_nc, diag_nc = run(noncentered, kind)

    print(f"{'variant':<14} {'site':<18} {'max R-hat':>10} {'min ESS':>8}")
    for tag, diag in [("centered", diag_c), ("non-centered", diag_nc)]:
        for site, (rhat, ess) in diag.items():
            print(f"{tag:<14} {site:<18} {rhat:>10.3f} {ess:>8.0f}")

    worst_c = max(r for r, _ in diag_c.values())
    worst_nc = max(r for r, _ in diag_nc.values())
    print(f"\ncentered      worst R-hat: {worst_c:.3f} "
          f"({'FAILS' if worst_c >= 1.05 else 'passes'} the 1.05 cut)")
    print(f"non-centered  worst R-hat: {worst_nc:.3f} "
          f"({'FAILS' if worst_nc >= 1.05 else 'passes'} the 1.05 cut)")
    assert worst_nc < 1.05, "non-centered chains failed to converge"

    if kind == "chees":
        # same executor, different kernel: the ensemble's posterior summary
        # must agree with NUTS within Monte-Carlo error
        mcmc_ref, _ = run(noncentered, "nuts")
        print(f"\n{'site':<8} {'ChEES mean':>12} {'NUTS mean':>12}")
        for site in ("mu", "tau"):
            a = float(mcmc_nc.get_samples()[site].mean())
            b = float(mcmc_ref.get_samples()[site].mean())
            print(f"{site:<8} {a:>12.3f} {b:>12.3f}")
            assert abs(a - b) < 1.0, \
                f"{site}: ChEES {a:.3f} vs NUTS {b:.3f} beyond MC error"
        print("ChEES and NUTS posterior summaries agree (within MC error)")

    # the reparameterized model still exposes `theta`: Predictive substitutes
    # the posterior draws of (mu, tau, theta_decentered) and the handler
    # recomputes theta as its deterministic function, batched under vmap
    post = Predictive(noncentered, mcmc_nc.get_samples(),
                      return_sites=["theta", "obs"])(random.PRNGKey(1))
    print(f"\nposterior-predictive theta mean per school: "
          f"{jnp.round(post['theta'].mean(0), 1)}")
    print(f"posterior-predictive obs   shape: {post['obs'].shape}")


if __name__ == "__main__":
    main()
