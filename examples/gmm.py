"""Gaussian mixture model with exact discrete marginalization.

The component assignment ``z_i`` is a latent ``Categorical`` — a site NUTS
cannot move.  Nothing in the model says so: the enumeration subsystem
(`repro.core.infer.enum`) detects the enumerable discrete latent during
``initialize_model``, broadcasts its support into a fresh leftmost batch dim,
and sums it out inside every (jit-compiled) potential-energy evaluation, so
the *same* chunked-scan NUTS executor that runs continuous models samples
``weights``/``mu``/``sigma`` from the exactly-marginalized posterior.

Afterwards, ``infer_discrete`` recovers the assignments' posterior given the
continuous draws (exact conditioning on the enumeration tensor), and the
diagnostics summary reports the integer-valued sites as mode/frequency
instead of meaningless R-hat.

    PYTHONPATH=src python examples/gmm.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax import random

import repro.core as pc
from repro.core import dist
from repro.core.handlers import substitute
from repro.core.infer import MCMC, NUTS, infer_discrete, print_summary

K, N = 2, 80


def make_data(rng_key):
    k1, k2 = random.split(rng_key)
    comp = random.bernoulli(k1, 0.35, (N,)).astype(jnp.int32)
    x = jnp.where(comp == 1, 2.5, -2.5) + 0.6 * random.normal(k2, (N,))
    return x, comp


def gmm(x):
    weights = pc.sample("weights", dist.Dirichlet(jnp.ones(K)))
    mu = pc.sample("mu",
                   dist.Normal(jnp.zeros(K), 5.0 * jnp.ones(K)).to_event(1))
    sigma = pc.sample("sigma", dist.HalfNormal(2.0))
    with pc.plate("data", x.shape[0]):
        z = pc.sample("z", dist.Categorical(probs=weights))
        pc.sample("obs", dist.Normal(mu[z], sigma), obs=x)


def main():
    x, comp = make_data(random.PRNGKey(0))

    # one compiled program: warmup + sampling, z marginalized per leapfrog
    mcmc = MCMC(NUTS(gmm), num_warmup=300, num_samples=300)
    mcmc.run(random.PRNGKey(1), x)
    samples = mcmc.get_samples()
    print("continuous sites sampled by NUTS:", sorted(samples))
    mcmc.print_summary()

    # posterior assignments given the last 64 continuous draws, vmapped
    tail = {k: v[-64:] for k, v in samples.items()}
    keys = random.split(random.PRNGKey(2), 64)

    def assignments(draw, key):
        return infer_discrete(substitute(gmm, data=draw), key)(x)["z"]

    zs = jax.vmap(assignments)(tail, keys)          # (64, N) int32
    print_summary({"z": np.asarray(zs)[None, :, :8]})  # first 8 points

    z_mode = np.asarray((zs.mean(0) > 0.5).astype(np.int32))
    acc = float(np.mean(z_mode == np.asarray(comp)))
    acc = max(acc, 1.0 - acc)  # mixtures are label-symmetric
    print(f"\nassignment accuracy vs ground truth: {acc:.3f}")
    assert acc > 0.95


if __name__ == "__main__":
    main()
