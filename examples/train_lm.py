"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps on the synthetic pipeline, with checkpoints and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M params: 12 layers, d_model=512, 8 heads, d_ff=2048, vocab=32768.
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param config in the qwen3 family
    cfg = dataclasses.replace(
        get_config("qwen3-8b"), name="qwen3-100m", num_layers=12,
        d_model=512, num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768, max_seq=1024, dtype="float32")

    n = sum(p.size for p in jax.tree.leaves(
        LM(cfg).init(jax.random.PRNGKey(0))))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

    train_mod.main([
        "--steps", str(args.steps),
        "--seq-len", "256", "--global-batch", "8",
        "--lr", "3e-4", "--ckpt-dir", args.ckpt_dir,
        "--checkpoint-every", "100", "--log-every", "20",
    ], cfg_override=cfg)


if __name__ == "__main__":
    main()
