"""Stochastic minibatch VI on logistic regression via plate subsampling.

The model below is written once, full-batch; passing ``subsample_size=B``
makes the plate draw a fresh random minibatch of indices *inside* the model
on every SVI step (seeded from the SVI state's rng key), ``subsample`` picks
the matching data rows, and the plate rescales the minibatch likelihood by
``N / B`` so the ELBO estimate stays unbiased.  Because ``SVI.update`` is a
pure function of ``(state, data)``, ``jax.jit(svi.update)`` compiles exactly
one step program and reuses it for every minibatch.

    PYTHONPATH=src python examples/minibatch_svi.py
"""
import time

import jax
import jax.numpy as jnp
from jax import random

import repro.core as pc
from repro import optim
from repro.core import dist
from repro.core.infer import SVI, AutoNormal, Trace_ELBO

N, D, B = 1000, 3, 100
TRUE_COEFS = jnp.array([1.0, 2.0, 3.0])


def make_model(subsample_size=None):
    def model(x, y=None):
        m = pc.sample("m", dist.Normal(0.0, jnp.ones(D)).to_event(1))
        b = pc.sample("b", dist.Normal(0.0, 1.0))
        with pc.plate("N", N, subsample_size=subsample_size):
            xb = pc.subsample(x, event_dim=1)
            yb = pc.subsample(y, event_dim=0) if y is not None else None
            pc.sample("y", dist.Bernoulli(logits=xb @ m + b), obs=yb)
    return model


def fit(model, x, y, num_steps, seed=1):
    guide = AutoNormal(model)
    svi = SVI(model, guide, optim.adam(5e-2), Trace_ELBO())
    state = svi.init(random.PRNGKey(seed), x, y)
    step = jax.jit(svi.update)
    t0 = time.time()
    for _ in range(num_steps):
        state, loss = step(state, x, y)
    elapsed = time.time() - t0
    return guide.median(svi.get_params(state))["m"], float(loss), elapsed


def main():
    x = random.normal(random.PRNGKey(0), (N, D))
    y = dist.Bernoulli(logits=x @ TRUE_COEFS).sample(rng_key=random.PRNGKey(3))

    m_full, loss_full, t_full = fit(make_model(), x, y, num_steps=1000)
    m_mb, loss_mb, t_mb = fit(make_model(subsample_size=B), x, y,
                              num_steps=2000)

    print(f"true coefficients:           {TRUE_COEFS}")
    print(f"full-batch   (N={N}):  {jnp.round(m_full, 2)}  "
          f"[1000 steps, {t_full:.1f}s]")
    print(f"minibatch    (B={B}):   {jnp.round(m_mb, 2)}  "
          f"[2000 steps, {t_mb:.1f}s, one compiled step]")
    gap = float(jnp.max(jnp.abs(m_mb - m_full)))
    print(f"max |minibatch - full|: {gap:.3f}")
    assert gap < 0.5, "minibatch VI diverged from the full-batch optimum"


if __name__ == "__main__":
    main()
