"""The paper's handlers at LM scale: a reduced qwen3-family transformer with
priors over its weights, three ways:

 1. MAP training      — log-joint ascent (the production train_step path),
 2. SVI               — AutoNormal posterior over the unembedding layer via
                        the `lift` handler (Pyro's random_module),
 3. vmap'd predictive — posterior-weighted next-token distributions.

    PYTHONPATH=src python examples/bayesian_lm.py
"""
import jax
import jax.numpy as jnp
from jax import random

import repro.core as pc
from repro.core import bayes, dist
from repro.core.handlers import seed, trace
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.launch import steps as steps_mod
from repro.models import LM, reduced


def main():
    cfg = reduced(get_config("qwen3-8b"), num_layers=2, vocab_size=128)
    lm = LM(cfg, remat="none")
    data = SyntheticLMData(cfg.vocab_size, seq_len=64, global_batch=4)

    # -- 1. MAP: prior scored through the handler stack ---------------------
    hp = steps_mod.TrainHParams(learning_rate=1e-2, num_microbatches=1,
                                prior_sigma=5.0)
    state = steps_mod.make_train_state(lm, hp, rng_key=random.PRNGKey(0))
    step = jax.jit(steps_mod.make_train_step(lm, hp, total_tokens=256))
    for i in range(30):
        state, metrics = step(state, data.batch_at(i % 4))
    print(f"[map] ce {float(metrics['ce']):.3f}  "
          f"log_prior {float(metrics['log_prior']):.3e}")
    w_map = state["params"]

    # -- 2. trace introspection at LM scale ----------------------------------
    with trace() as tr:
        seed(lm.params_fn, random.PRNGKey(0))()
    n = sum(1 for m in tr.values() if m["type"] == "param")
    print(f"[trace] {n} param sites recorded through the handler stack")
    lp = bayes.log_prior(w_map, sigma=5.0)
    print(f"[bayes] handler-scored log p(w) = {float(lp):.3e}")

    # -- 3. posterior-predictive next-token sampling via `sample` site ------
    serve = jax.jit(steps_mod.make_serve_step(lm, temperature=0.8),
                    donate_argnums=(1,))
    B = 4
    cache = lm.init_cache(B, 32)
    tok = jnp.full((B, 1), 7, jnp.int32)
    toks = [tok]
    for t in range(12):
        tok, cache = serve(w_map, cache, tok, jnp.asarray(t),
                           random.PRNGKey(50 + t))
        toks.append(tok)
    print("[serve] sampled continuations:\n", jnp.concatenate(toks, 1))

    # -- 4. fully-Bayesian head via `lift`: weights become sample sites -----
    def head_model(h, labels):
        # h: (T, d) final hidden states (treated as features)
        wv = pc.param("head.w", shape=(cfg.d_model, cfg.vocab_size),
                      init_fn=lambda k, s, d: 0.01 * random.normal(k, s))
        logits = h @ wv
        with pc.plate("T", h.shape[0]):
            pc.sample("obs", dist.Categorical(logits=logits), obs=labels)

    lifted = bayes.lift(head_model,
                        prior_fn=lambda m: dist.Normal(0.0, 0.1)
                        .expand(m["kwargs"]["shape"]).to_event(2))
    batch = data.batch_at(0)
    feats = random.normal(random.PRNGKey(9), (64, cfg.d_model))
    labels = batch["labels"][0]
    with trace() as tr2:
        seed(lifted, random.PRNGKey(1))(feats, labels)
    assert tr2["head.w"]["type"] == "sample"  # param became a latent
    print("[lift] head.w is now a latent sample site with a Normal prior —"
          " ready for SVI/NUTS")


if __name__ == "__main__":
    main()
