"""Vectorized multi-chain NUTS on the paper's HMM benchmark model with the
unified executor: chains batched by ``vmap`` into one XLA program (Sec 3.2),
run in compiled chunks with *real* mid-run checkpointing — a preempted
relaunch resumes from ``latest_step`` and lands on bit-identical draws.

    PYTHONPATH=src python examples/multichain_hmm.py
"""
import os
import sys
import time

import numpy as np
from jax import random

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.models import hmm_data, hmm_model  # noqa: E402
from repro.core.infer import MCMC, NUTS, print_summary
from repro.distributed import checkpoint as ckpt

CKPT_DIR = "/tmp/repro_hmm_chains"


def make_mcmc():
    return MCMC(NUTS(hmm_model), num_warmup=200, num_samples=200,
                num_chains=4, chain_method="vectorized")


def main():
    data = hmm_data(T=200, T_sup=50)

    # chunked run: full chain state + collected draws persisted every 100
    # iterations through repro.distributed.checkpoint (atomic dir swap)
    mcmc = make_mcmc()
    t0 = time.time()
    mcmc.run(random.PRNGKey(0), data, checkpoint_every=100,
             checkpoint_dir=CKPT_DIR)
    print(f"4 vectorized chains in {time.time()-t0:.1f}s "
          f"(one XLA program per chunk, chains batched by vmap)")
    print_summary(mcmc.get_samples(group_by_chain=True))

    # fault tolerance: a relaunched worker resumes from the persisted step.
    # Here the checkpoint is already complete, so resume=True rebuilds the
    # full sample set from disk without re-running a single transition —
    # after a mid-run preemption it would continue from the last chunk.
    print(f"checkpoint on disk at step "
          f"{ckpt.latest_step(os.path.join(CKPT_DIR, 'state'))}")
    resumed = make_mcmc()
    t1 = time.time()
    resumed.run(random.PRNGKey(0), data, checkpoint_dir=CKPT_DIR,
                resume=True)
    np.testing.assert_array_equal(
        np.asarray(resumed.get_samples()["theta"]),
        np.asarray(mcmc.get_samples()["theta"]))
    print(f"resume from checkpoint: bit-identical samples in "
          f"{time.time()-t1:.1f}s (no transitions replayed)")


if __name__ == "__main__":
    main()
