"""Vectorized multi-chain NUTS on the paper's HMM benchmark model, with
cross-chain diagnostics and chain checkpointing — the Sec 3.2 claim
("running MCMC chains ... batched with vmap") as a runnable script.

    PYTHONPATH=src python examples/multichain_hmm.py
"""
import time

from jax import random

from benchmarks.models import hmm_data, hmm_model
from repro.core.infer import MCMC, NUTS, print_summary
from repro.distributed import checkpoint as ckpt


def main():
    data = hmm_data(T=200, T_sup=50)
    mcmc = MCMC(NUTS(hmm_model), num_warmup=200, num_samples=200,
                num_chains=4, chain_method="vectorized")
    t0 = time.time()
    mcmc.run(random.PRNGKey(0), data)
    print(f"4 vectorized chains in {time.time()-t0:.1f}s "
          f"(one XLA program, chains batched by vmap)")
    print_summary(mcmc.get_samples(group_by_chain=True))

    # fault tolerance: persist all chain states; a preempted worker restores
    ckpt.save(mcmc.last_state, "/tmp/repro_hmm_chains", step=200)
    restored, step, _ = ckpt.restore(mcmc.last_state,
                                     "/tmp/repro_hmm_chains")
    print(f"chain state checkpoint round-trip ok at step {step}")


if __name__ == "__main__":
    main()
