"""Run every benchmark (one per paper table/figure) + the roofline table.

``python -m benchmarks.run``          — full paper-spec settings
``python -m benchmarks.run --quick``  — reduced step counts (CI / smoke)
"""
import json
import os
import sys
import time

RESULTS = "benchmarks/results"


def main():
    quick = "--quick" in sys.argv or os.environ.get("BENCH_QUICK") == "1"
    os.makedirs(RESULTS, exist_ok=True)
    t0 = time.time()
    out = {}

    from benchmarks import hmm, logreg, skim
    print("=" * 70)
    print("Table 2a — HMM (time per leapfrog step)")
    print("=" * 70, flush=True)
    out["hmm"] = hmm.main(quick=quick)

    print("=" * 70)
    print("Table 2a — logistic regression / CoverType-shaped")
    print("=" * 70, flush=True)
    out["logreg"] = logreg.main(quick=quick)

    print("=" * 70)
    print("Fig 2b — SKIM time per effective sample vs p")
    print("=" * 70, flush=True)
    out["skim"] = skim.main(quick=quick)

    print("=" * 70)
    print("Roofline (from dry-run artifacts; see EXPERIMENTS.md)")
    print("=" * 70, flush=True)
    try:
        from benchmarks import roofline
        roofline.main()
        out["roofline_rows"] = roofline.table(roofline.load())
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"[roofline skipped: {e}]")

    out["total_wall_s"] = time.time() - t0
    with open(os.path.join(RESULTS, "bench_summary.json"), "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nall benchmarks done in {out['total_wall_s']:.0f}s; summary in "
          f"{RESULTS}/bench_summary.json")


if __name__ == "__main__":
    main()
