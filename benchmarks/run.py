"""Run every benchmark (one per paper table/figure) + the roofline table.

``python -m benchmarks.run``          — full paper-spec settings
``python -m benchmarks.run --quick``  — reduced step counts (CI / smoke)
``--profile``                         — wrap each section in a
                                        ``jax.profiler.trace`` (perfetto
                                        dirs under results/profile/)
"""
import contextlib
import json
import os
import sys
import time

RESULTS = "benchmarks/results"


def _profiler(enabled):
    """Per-section ``jax.profiler.trace`` wrapper (inert when disabled)."""
    if not enabled:
        return lambda name: contextlib.nullcontext()
    import jax

    base = os.path.join(RESULTS, "profile")

    def section(name):
        trace_dir = os.path.join(base, name)
        print(f"[profiling -> {trace_dir}]", flush=True)
        return jax.profiler.trace(trace_dir)

    return section


def _previous_headlines():
    """Headline metrics of the last recorded run, carried forward into the
    new summary so each bench_summary.json shows before/after per PR."""
    path = os.path.join(RESULTS, "bench_summary.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            prev = json.load(f)
    except Exception:
        return None
    keep = {}
    for k in ("hmm", "logreg", "skim"):
        if isinstance(prev.get(k), dict):
            keep[k] = {m: prev[k][m]
                       for m in ("ms_per_leapfrog", "ms_per_eff_sample",
                                 "wall_s")
                       if m in prev[k]}
    for k in ("multichain", "svi_minibatch", "enum_hmm", "chees",
              "sharded_potential"):
        if isinstance(prev.get(k), dict):
            keep[k] = {"rows": prev[k].get("rows")}
            if "ess_per_sec_ratio_at_max_chains" in prev[k]:
                keep[k]["ess_per_sec_ratio_at_max_chains"] = \
                    prev[k]["ess_per_sec_ratio_at_max_chains"]
    if isinstance(prev.get("kernels"), dict):
        kern = prev["kernels"]
        keep["kernels"] = {
            "ops": kern.get("ops"),
            "copy_bandwidth_gbs": kern.get("copy_bandwidth_gbs"),
            "nuts_glm_ms_per_leapfrog_speedup":
                (kern.get("nuts_glm") or {}).get("ms_per_leapfrog_speedup"),
            "chees_64_warm_wall_s":
                (kern.get("chees_64_chains") or {}).get("wall_s"),
        }
    return keep or None


def _lint_bench():
    """Static-analysis overhead on the logreg model: the full lint pass is
    pure tracing (zero FLOPs), so its wall time is the entire cost a user
    pays for ``MCMC(..., validate=True)`` — once, on the cold path."""
    from benchmarks.models import covtype_data, logreg_model
    from repro.lint import lint_model

    data = covtype_data(n=5000)
    t0 = time.time()
    result = lint_model(logreg_model, (data["x"],), {"y": data["y"]})
    lint_ms = (time.time() - t0) * 1e3
    rec = {"benchmark": "lint_logreg", "n": 5000, "lint_ms": lint_ms,
           "ok": result.ok, "codes": sorted(result.codes())}
    print(f"lint_model(logreg, n=5000): {lint_ms:.1f} ms, "
          f"ok={result.ok}", flush=True)
    return rec


def main():
    quick = "--quick" in sys.argv or os.environ.get("BENCH_QUICK") == "1"
    profile = _profiler("--profile" in sys.argv)
    os.makedirs(RESULTS, exist_ok=True)
    t0 = time.time()
    out = {}
    previous = _previous_headlines()

    # every summary records where it was measured (the trajectory in
    # BENCH_<n>.json is only comparable within one environment)
    from repro.obs import collect_environment
    out["environment"] = collect_environment()

    from benchmarks import (chees, enum_hmm, hmm, logreg, multichain,
                            obs_overhead, skim, svi_minibatch)
    from benchmarks import kernels_bench, sharded_potential

    sections = [
        ("hmm", "Table 2a — HMM (time per leapfrog step)", hmm.main),
        ("enum_hmm", "Enum HMM — fully latent states, ms/leapfrog vs K "
         "(markov + enum_contract)", enum_hmm.main),
        ("logreg", "Table 2a — logistic regression / CoverType-shaped",
         logreg.main),
        ("multichain", "Multi-chain throughput (chains × samples/sec, vmap "
         "executor)", multichain.main),
        ("chees", "ChEES-HMC vs NUTS (samples/sec + ESS/sec vs chain "
         "count)", chees.main),
        ("svi_minibatch", "Minibatch SVI (steps/sec vs subsample size, one "
         "compiled step)", svi_minibatch.main),
        ("skim", "Fig 2b — SKIM time per effective sample vs p", skim.main),
        ("kernels", "Hot-path kernels — per-op ms + roofline fraction, GLM "
         "fused vs plain, ChEES 64-chain warm wall", kernels_bench.main),
        ("sharded_potential", "Data-sharded GLM potential — ms/eval vs mesh "
         "data-axis size (8 virtual devices, chains x data mesh)",
         sharded_potential.main),
        ("obs_overhead", "Telemetry overhead — logreg quick warm wall, "
         "metrics off vs on vs convergence-gated (budget < 3%)",
         obs_overhead.main),
        ("lint", "Static analyzer — lint_ms on logreg (cost of "
         "validate=True)", lambda quick: _lint_bench()),
    ]
    for key, title, fn in sections:
        print("=" * 70)
        print(title)
        print("=" * 70, flush=True)
        with profile(key):
            out[key] = fn(quick=quick)

    print("=" * 70)
    print("Roofline (from dry-run artifacts; see EXPERIMENTS.md)")
    print("=" * 70, flush=True)
    try:
        from benchmarks import roofline
        roofline.main()
        out["roofline_rows"] = roofline.table(roofline.load())
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"[roofline skipped: {e}]")

    out["total_wall_s"] = time.time() - t0
    if previous is not None:
        out["previous"] = previous
    with open(os.path.join(RESULTS, "bench_summary.json"), "w") as f:
        json.dump(out, f, indent=1)
    # per-PR snapshot: bench_summary.json is overwritten every run, the
    # BENCH_<n>.json files accumulate the trajectory
    with open(os.path.join(RESULTS, "BENCH_10.json"), "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nall benchmarks done in {out['total_wall_s']:.0f}s; summary in "
          f"{RESULTS}/bench_summary.json (snapshot: BENCH_10.json)")


if __name__ == "__main__":
    main()
