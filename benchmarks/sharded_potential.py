"""Data-sharded GLM potential scaling: ms per chain-batched
potential+gradient evaluation (the leapfrog-dominant cost) vs the mesh
data-axis size, at n in {20k, 200k} (docs/distributed.md).

The timing runs in a subprocess with 8 virtual CPU devices so the
``(1, sd)`` meshes are real even when the parent process already
initialized jax on one device.  Virtual devices share the same cores, so
absolute speedups on this image understate real multi-chip scaling — the
recorded trajectory is what matters (a layout that stops compiling, or a
fold that starts re-evaluating every row on every device, shows up as a
step change here).
"""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
from jax import random
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.infer.glm import _make_sharded_nll
from repro.core.infer.hmc_util import chain_vmap
from repro.distributed.sharding import use_inference_mesh
from repro.launch.mesh import make_inference_mesh

cfg = json.loads(os.environ["SHARDED_BENCH_CFG"])
d, C, S = 8, 8, 8          # latent dim, chains, static fold shards
rows = []
for n in cfg["ns"]:
    x = random.normal(random.PRNGKey(0), (n, d))
    y = (random.uniform(random.PRNGKey(1), (n,)) < 0.5).astype(jnp.float32)
    nll = _make_sharded_nll(x, y, jnp.zeros(n), None, "bernoulli_logit", S)
    z = random.normal(random.PRNGKey(2), (C, d)) * 0.1

    def timed(f, zz):
        out = f(zz)
        jax.block_until_ready(out)          # compile + first touch
        reps, best = cfg["reps"], float("inf")
        for _ in range(3):                  # best-of-3 batches of reps
            t0 = time.perf_counter()
            for _ in range(reps):
                out = f(zz)
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / reps)
        return 1e3 * best

    base = jax.jit(lambda zz: jax.vmap(jax.value_and_grad(nll))(zz))
    rows.append({"n": n, "layout": "local", "ms_per_eval": timed(base, z)})
    for sd in (1, 2, 4, 8):
        mesh = make_inference_mesh(C, (1, sd))

        def sharded(zz):
            with use_inference_mesh(mesh, "data"):
                return chain_vmap(jax.value_and_grad(nll))(zz)

        zs = jax.device_put(z, NamedSharding(mesh, P("chains")))
        rows.append({"n": n, "layout": f"(1,{sd})",
                     "ms_per_eval": timed(jax.jit(sharded), zs)})
print(json.dumps({"rows": rows, "n_devices": len(jax.devices())}))
"""


def main(quick=False):
    # n=200k stays in quick mode: a potential eval is milliseconds, so the
    # headline scaling row costs a few compiles, not a long chain
    cfg = {"ns": [20_000, 200_000], "reps": 10 if quick else 30}
    env = dict(os.environ, SHARDED_BENCH_CFG=json.dumps(cfg),
               PYTHONPATH=os.pathsep.join(
                   p for p in ["src", os.environ.get("PYTHONPATH", "")] if p))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        print(f"[sharded_potential failed]\n{out.stderr[-2000:]}")
        return {"benchmark": "sharded_potential", "error":
                out.stderr.strip().splitlines()[-1][:300] if out.stderr
                else "subprocess failed"}
    got = json.loads(out.stdout.strip().splitlines()[-1])
    rec = {"benchmark": "sharded_potential", "n_devices": got["n_devices"],
           "data_shards": 8, "num_chains": 8, "rows": got["rows"]}
    for row in got["rows"]:
        print(f"n={row['n']:>7}  {row['layout']:>6}  "
              f"{row['ms_per_eval']:8.3f} ms/eval")
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
