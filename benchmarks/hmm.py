"""Paper Table 2a (HMM): time per leapfrog step, semi-supervised HMM.

Paper numbers (AMD 1920X, 1000+1000 steps): Stan 0.53 ms, Pyro 30.51 ms,
NumPyro 32-bit 0.09 ms / 64-bit 0.15 ms.  This container is a different
(1-core) CPU, so the comparison point is NumPyro-32bit's order of magnitude;
the paper's claim reproduced here is that the END-TO-END-JIT iterative NUTS
keeps per-leapfrog cost at the sub-millisecond level a graph-per-step
implementation (Pyro: ~30 ms) cannot reach.
"""
import json
import sys

from benchmarks.harness import run_nuts
from benchmarks.models import hmm_data, hmm_model


def main(quick=False):
    data = hmm_data()
    num = 100 if quick else 1000
    out = run_nuts(hmm_model, (data,), num_warmup=num, num_samples=num)
    rec = {"benchmark": "hmm_table2a", **out,
           "paper_ms_per_leapfrog": {"stan": 0.53, "pyro": 30.51,
                                     "numpyro32": 0.09, "numpyro64": 0.15}}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
