"""Shared benchmark harness: time-per-leapfrog-step and time-per-effective-
sample, the paper's two metrics (Table 2a, Fig 2b)."""
from __future__ import annotations

import time

import jax
import numpy as np
from jax import random

from repro.core.infer import MCMC, NUTS, effective_sample_size


def run_nuts(model, model_args=(), model_kwargs=None, *, num_warmup,
             num_samples, rng_seed=0, step_size=None, adapt=True,
             max_tree_depth=10):
    kw = model_kwargs or {}
    kernel_kwargs = dict(max_tree_depth=max_tree_depth)
    if step_size is not None:
        kernel_kwargs.update(step_size=step_size, adapt_step_size=adapt,
                             adapt_mass_matrix=adapt)
    kernel = NUTS(model, **kernel_kwargs)
    mcmc = MCMC(kernel, num_warmup=num_warmup, num_samples=num_samples)

    t0 = time.time()
    mcmc.run(random.PRNGKey(rng_seed), *model_args, **kw)
    jax.block_until_ready(mcmc.get_samples())
    cold = time.time() - t0
    # warm run: the whole chain is ONE cached XLA program (paper Sec 3.1) —
    # re-running with a new seed measures device time, no trace/compile
    t1 = time.time()
    mcmc.run(random.PRNGKey(rng_seed + 1), *model_args, **kw)
    jax.block_until_ready(mcmc.get_samples())
    wall = time.time() - t1
    # stable run: REPEAT the warm seed.  The first warm chunk still pays
    # one-off allocator/first-touch costs, and a fresh seed draws different
    # trajectories (different leapfrog counts), so wall_s alone makes
    # ms/leapfrog noisy across runs.  Same seed -> same program, same rng,
    # same trajectories as the run whose extras are read below.
    t2 = time.time()
    mcmc.run(random.PRNGKey(rng_seed + 1), *model_args, **kw)
    jax.block_until_ready(mcmc.get_samples())
    warm_wall = time.time() - t2

    extras = mcmc.get_extra_fields()
    n_leapfrog = int(np.sum(np.asarray(extras["num_steps"])))
    # warmup leapfrogs aren't collected; estimate with the sampling mean
    mean_steps = n_leapfrog / max(num_samples, 1)
    total_lf = n_leapfrog + mean_steps * num_warmup
    samples = mcmc.get_samples(group_by_chain=True)
    ess = {k: float(np.min(effective_sample_size(v)))
           for k, v in samples.items() if v.ndim >= 2}
    min_ess = min(ess.values()) if ess else float("nan")
    return {
        "wall_s": wall,
        "warm_wall_s": warm_wall,
        "compile_s": cold - wall,
        "num_leapfrog": int(total_lf),
        "ms_per_leapfrog": 1e3 * warm_wall / max(total_lf, 1),
        "min_ess": min_ess,
        "ms_per_eff_sample": 1e3 * warm_wall / max(min_ess, 1e-9),
        "mean_accept": float(np.mean(np.asarray(extras["accept_prob"]))),
        "divergences": int(np.sum(np.asarray(extras["diverging"]))),
    }
