"""Paper Table 2a (COVTYPE): logistic regression on a 581,012 x 54
CoverType-shaped dataset, fixed step size 0.0015, 40 samples (App. C).

Paper: Stan 135.94 ms, Pyro 32.76 ms, NumPyro 32-bit 30.11 ms per leapfrog
(CPU) — at this scale per-leapfrog cost is dominated by the (n x d) matmul
in the potential gradient, so the JIT win narrows: the reproduction target
is per-leapfrog time scaling with the matvec cost, not dispatch overhead.
"""
import json
import sys

from benchmarks.harness import run_nuts
from benchmarks.models import covtype_data, logreg_model


def main(quick=False):
    n = 20_000 if quick else 581_012
    data = covtype_data(n=n)
    if quick:
        # adaptive warmup + enough draws for a sane headline: the paper
        # spec (0 warmup, fixed 0.0015 step, a handful of draws) degrades
        # at n=20k into mean_accept=1.0 / 62 leapfrogs / min_ess~3 — pure
        # rng noise, useless as a CI perf trajectory
        out = run_nuts(logreg_model, (data["x"],), {"y": data["y"]},
                       num_warmup=150, num_samples=150)
    else:
        out = run_nuts(logreg_model, (data["x"],), {"y": data["y"]},
                       num_warmup=0, num_samples=40,
                       step_size=0.0015, adapt=False)
    rec = {"benchmark": "logreg_table2a", "n": n, **out,
           "paper_ms_per_leapfrog": {"stan": 135.94, "pyro": 32.76,
                                     "numpyro32": 30.11, "numpyro_gpu": 1.46}}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
