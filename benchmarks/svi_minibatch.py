"""Minibatch-SVI throughput: steps/sec vs subsample size, one compiled step.

The claim under test is architectural, not statistical: because plate
subsampling draws its minibatch indices *inside* the traced program (seeded
from the SVI state's rng key), `jax.jit(svi.update)` compiles exactly one
step executable per minibatch size and the whole optimization is dispatch +
device time — no per-step retracing, no host-side index shuffling.  We report
steps/sec across subsample sizes (full batch down to 1%), plus the one-off
compile time, on the CoverType-shaped logistic regression.
"""
import json
import sys
import time

import jax
from jax import random

import repro.core as pc
from repro import optim
from repro.core import dist
from repro.core.infer import SVI, AutoNormal, Trace_ELBO
from benchmarks.models import covtype_data


def _model(n, subsample_size):
    def model(x, y=None):
        d = x.shape[-1]
        m = pc.sample("m", dist.Normal(0.0, 1.0).expand((d,)).to_event(1))
        b = pc.sample("b", dist.Normal(0.0, 1.0))
        with pc.plate("N", n, subsample_size=subsample_size):
            xb = pc.subsample(x, event_dim=1)
            yb = pc.subsample(y, event_dim=0) if y is not None else None
            pc.sample("y", dist.Bernoulli(logits=xb @ m + b), obs=yb)
    return model


def main(quick=False):
    n, d = (2_000, 54) if quick else (10_000, 54)
    steps = 200 if quick else 1_000
    data = covtype_data(n=n, d=d)
    x, y = data["x"], data["y"]
    sweep = [None, n // 10, n // 100]
    rows = []
    for sub in sweep:
        model = _model(n, sub)
        svi = SVI(model, AutoNormal(model), optim.adam(5e-2), Trace_ELBO())
        state = svi.init(random.PRNGKey(0), x, y)
        step = jax.jit(svi.update)
        t0 = time.time()
        state, _ = step(state, x, y)
        state, _ = step(state, x, y)  # weak-type stabilization recompile
        jax.block_until_ready(state.params)
        compile_s = time.time() - t0
        t1 = time.time()
        for _ in range(steps):
            state, loss = step(state, x, y)
        jax.block_until_ready(loss)
        wall = time.time() - t1
        rows.append({"subsample_size": sub or n,
                     "steps_per_sec": steps / wall,
                     "wall_s": wall, "compile_s": compile_s,
                     "final_loss": float(loss)})
        print(f"  B={sub or n:6d}  {rows[-1]['steps_per_sec']:9.1f} steps/s "
              f"(warm wall {wall:.2f}s for {steps} steps, compile "
              f"{compile_s:.1f}s)", flush=True)
    rec = {"benchmark": "svi_minibatch", "model": f"logreg n={n} d={d}",
           "num_steps": steps, "rows": rows}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
