"""Paper Fig 2b (SKIM): time per effective sample vs dimensionality p.

Paper sweeps p for Stan vs NumPyro with 1000+1000 steps; the claim is
consistently lower overhead for NumPyro's end-to-end-compiled NUTS as p
grows.  We sweep a reduced p-grid sized to this 1-core container and report
ms/effective-sample per p.
"""
import json
import sys

from benchmarks.harness import run_nuts
from benchmarks.models import skim_data, skim_model


def main(quick=False):
    ps = [32, 64] if quick else [32, 64, 128, 256]
    num = 100 if quick else 400
    recs = []
    for p in ps:
        data = skim_data(p)
        out = run_nuts(skim_model, (data["x"],), {"y": data["y"]},
                       num_warmup=num, num_samples=num, max_tree_depth=8)
        recs.append({"p": p, **out})
        print(f"[skim] p={p}: {out['ms_per_eff_sample']:.2f} ms/eff-sample "
              f"({out['min_ess']:.0f} ESS, {out['divergences']} div)",
              flush=True)
    rec = {"benchmark": "skim_fig2b", "sweep": recs}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
