"""Fully-latent HMM via enumeration: ms/leapfrog and total wall time vs the
number of hidden states K.

Unlike ``benchmarks/hmm.py`` (the paper's semi-supervised HMM, which
hand-codes a forward pass and observes a supervised prefix), this model has
*no* supervision and *no* manual marginalization — the hidden states are
summed out by the ``markov`` combinator of ``repro.core.infer.enum`` at
O(T·K²) per potential evaluation, inside the same end-to-end-jit'd NUTS
executor.  Sweeping K verifies the quadratic (not exponential) cost shape
and tracks the enum_contract kernel's hot path.
"""
import json
import sys

from benchmarks.harness import run_nuts
from benchmarks.models import enum_hmm_data, enum_hmm_model


def main(quick=False):
    ks = (2, 4) if quick else (2, 4, 8)
    num = 50 if quick else 300
    T = 60 if quick else 120
    rows = []
    for k in ks:
        data = enum_hmm_data(k, T=T)
        out = run_nuts(enum_hmm_model, (data,), num_warmup=num,
                       num_samples=num, max_tree_depth=8)
        rows.append({"K": k, "T": T,
                     "ms_per_leapfrog": out["ms_per_leapfrog"],
                     "wall_s": out["wall_s"],
                     "compile_s": out["compile_s"],
                     "min_ess": out["min_ess"],
                     "divergences": out["divergences"]})
        print(f"K={k:3d}  ms/leapfrog={out['ms_per_leapfrog']:8.3f}  "
              f"wall={out['wall_s']:7.2f}s  compile={out['compile_s']:6.1f}s",
              flush=True)
    rec = {"benchmark": "enum_hmm", "rows": rows}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
