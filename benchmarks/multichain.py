"""Multi-chain throughput: chains × samples/sec under the unified executor.

The paper's Sec 3.2 claim — "running MCMC chains ... batched with vmap" —
measured rather than asserted: the same compiled chain program is batched
over a growing chain count and we record aggregate post-warmup samples per
second on the *warm* (cache-hit) run.  Near-linear scaling until the device
saturates is the signature of the single-program vmap executor; a
dispatch-per-chain driver flattens immediately.
"""
import json
import sys
import time

import jax
from jax import random

from benchmarks.models import covtype_data, logreg_model
from repro.core.infer import MCMC, NUTS


def main(quick=False):
    n, d = 2_000, 54
    data = covtype_data(n=n, d=d)
    warm, samp = (50, 50) if quick else (100, 100)
    sweep = (1, 8) if quick else (1, 4, 16)
    rows = []
    for chains in sweep:
        mcmc = MCMC(NUTS(logreg_model), num_warmup=warm, num_samples=samp,
                    num_chains=chains, chain_method="vectorized")
        t0 = time.time()
        mcmc.run(random.PRNGKey(0), data["x"], y=data["y"])
        jax.block_until_ready(mcmc.get_samples())
        cold = time.time() - t0
        t1 = time.time()
        mcmc.run(random.PRNGKey(1), data["x"], y=data["y"])
        jax.block_until_ready(mcmc.get_samples())
        wall = time.time() - t1
        rows.append({"chains": chains,
                     "samples_per_sec": chains * samp / wall,
                     "wall_s": wall, "compile_s": cold - wall})
        print(f"  chains={chains:3d}  {rows[-1]['samples_per_sec']:9.1f} "
              f"samples/s  (warm wall {wall:.2f}s, compile "
              f"{cold - wall:.1f}s)", flush=True)
    rec = {"benchmark": "multichain_throughput",
           "model": f"logreg n={n} d={d}", "num_warmup": warm,
           "num_samples": samp, "rows": rows}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
