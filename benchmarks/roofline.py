"""Roofline aggregation: read the dry-run JSON records and emit the
EXPERIMENTS.md §Roofline table (single-pod baselines per the assignment),
plus the *measured* roofline for the MCMC hot-path kernels — every one is
memory-bound (~1 FLOP per element), so the ceiling is streaming bandwidth,
measured here with a jit'd copy rather than quoted from a datasheet."""
import glob
import json
import os
import sys
import time


def load(results_dir="benchmarks/results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def table(recs, mesh="16x16"):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("variants"):   # §Perf variant runs: not baselines
            continue
        if "skipped" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": r["skipped"]})
            continue
        if "error" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r["error"]})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "memory_s_flashproj": r.get("memory_s_flashproj",
                                        r["memory_s"]),
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": r["model_flops_global"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_fraction": r["roofline_fraction"],
            "bytes_per_device_GB":
                (r.get("argument_size_in_bytes") or 0) / 1e9,
            "temp_GB": (r.get("temp_size_in_bytes") or 0) / 1e9,
        })
    return rows


def markdown(rows):
    hdr = ("| arch | shape | compute s | memory s | mem s (flash-proj) | "
           "collective s | dominant | useful | roofline frac | args GB/dev "
           "| temps GB/dev |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skip | — | — | — | — |")
            continue
        ur = (f"{r['useful_ratio']:.2f}" if r["useful_ratio"] is not None
              else "—")
        rf = (f"{r['roofline_fraction']:.3f}"
              if r["roofline_fraction"] is not None else "—")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['memory_s_flashproj']:.3g} | "
            f"{r['collective_s']:.3g} | "
            f"{r['dominant']} | {ur} | "
            f"{rf} | {r['bytes_per_device_GB']:.2f} "
            f"| {r['temp_GB']:.2f} |")
    return "\n".join(lines)


def copy_bandwidth_gbs(nbytes=64 << 20, iters=10):
    """Achievable streaming bandwidth of the current backend, measured: a
    jit'd ``x + 1.0`` over an ``nbytes`` array reads and writes the whole
    buffer (2x traffic), timed best-of-``iters``.  This is the roofline the
    memory-bound MCMC kernels are scored against — the same machine, the
    same allocator, not a datasheet number."""
    import jax
    import jax.numpy as jnp

    x = jnp.zeros(nbytes // 4, jnp.float32)
    bump = jax.jit(lambda a: a + 1.0)
    bump(x).block_until_ready()          # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        bump(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 2.0 * nbytes / best / 1e9


def kernel_fraction(bytes_moved, seconds, peak_gbs):
    """Achieved-vs-roofline fraction for one memory-bound kernel call."""
    if not seconds or not peak_gbs:
        return None
    return (bytes_moved / seconds / 1e9) / peak_gbs


def kernel_markdown(rows, peak_gbs):
    """EXPERIMENTS.md-style table for the MCMC hot-path kernel rows
    produced by ``benchmarks.kernels_bench``.  Each row may carry its own
    ``peak_gbs`` — the copy bandwidth measured at *that op's* working-set
    size (a 5 MB op is cache-resident where a 64 MB copy is DRAM-bound;
    scoring one against the other inflates fractions past 1)."""
    hdr = ("| op | shape | bytes/call MB | roofline GB/s | ref ms | "
           "ref frac | pallas ms | pallas frac |")
    lines = [hdr, "|" + "---|" * 8]
    for r in rows:
        peak = r.get("peak_gbs", peak_gbs)

        def fmt(ms, peak=peak, nbytes=r["bytes_moved"]):
            if ms is None:
                return "—", "—"
            frac = kernel_fraction(nbytes, ms / 1e3, peak)
            return f"{ms:.3f}", f"{frac:.2f}"
        rm, rf = fmt(r.get("ref_ms"))
        pm, pf = fmt(r.get("pallas_ms"))
        lines.append(f"| {r['op']} | {r['shape']} | "
                     f"{r['bytes_moved'] / 1e6:.1f} | {peak:.1f} | "
                     f"{rm} | {rf} | {pm} | {pf} |")
    lines.append(f"\nstreaming copy at 64 MB (DRAM): {peak_gbs:.1f} GB/s")
    return "\n".join(lines)


def main():
    recs = load(sys.argv[1] if len(sys.argv) > 1 else
                "benchmarks/results/dryrun")
    rows = table(recs)
    print(markdown(rows))
    ok = [r for r in rows if "skipped" not in r and "error" not in r]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["collective_s"] /
                   max(r["compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} {worst['shape']} "
              f"({worst['roofline_fraction']:.4f})")
        print(f"most collective-bound: {coll['arch']} {coll['shape']} "
              f"(coll/comp = "
              f"{coll['collective_s']/max(coll['compute_s'],1e-12):.1f}x)")


if __name__ == "__main__":
    main()
