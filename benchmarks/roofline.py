"""Roofline aggregation: read the dry-run JSON records and emit the
EXPERIMENTS.md §Roofline table (single-pod baselines per the assignment)."""
import glob
import json
import os
import sys


def load(results_dir="benchmarks/results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def table(recs, mesh="16x16"):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("variants"):   # §Perf variant runs: not baselines
            continue
        if "skipped" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": r["skipped"]})
            continue
        if "error" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r["error"]})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "memory_s_flashproj": r.get("memory_s_flashproj",
                                        r["memory_s"]),
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": r["model_flops_global"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_fraction": r["roofline_fraction"],
            "bytes_per_device_GB":
                (r.get("argument_size_in_bytes") or 0) / 1e9,
            "temp_GB": (r.get("temp_size_in_bytes") or 0) / 1e9,
        })
    return rows


def markdown(rows):
    hdr = ("| arch | shape | compute s | memory s | mem s (flash-proj) | "
           "collective s | dominant | useful | roofline frac | args GB/dev "
           "| temps GB/dev |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skip | — | — | — | — |")
            continue
        ur = (f"{r['useful_ratio']:.2f}" if r["useful_ratio"] is not None
              else "—")
        rf = (f"{r['roofline_fraction']:.3f}"
              if r["roofline_fraction"] is not None else "—")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['memory_s_flashproj']:.3g} | "
            f"{r['collective_s']:.3g} | "
            f"{r['dominant']} | {ur} | "
            f"{rf} | {r['bytes_per_device_GB']:.2f} "
            f"| {r['temp_GB']:.2f} |")
    return "\n".join(lines)


def main():
    recs = load(sys.argv[1] if len(sys.argv) > 1 else
                "benchmarks/results/dryrun")
    rows = table(recs)
    print(markdown(rows))
    ok = [r for r in rows if "skipped" not in r and "error" not in r]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["collective_s"] /
                   max(r["compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} {worst['shape']} "
              f"({worst['roofline_fraction']:.4f})")
        print(f"most collective-bound: {coll['arch']} {coll['shape']} "
              f"(coll/comp = "
              f"{coll['collective_s']/max(coll['compute_s'],1e-12):.1f}x)")


if __name__ == "__main__":
    main()
