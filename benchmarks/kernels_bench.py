"""Hot-path kernel benchmarks: the ops behind the PR-7 roofline expansion.

Four measurements, all feeding the ``kernels`` section of
``bench_summary.json``:

1. **per-op ms + roofline fraction** — `leapfrog_halfstep_batch`,
   `mala_step`, `glm_potential_grad` on hot-path shapes, scored against the
   *measured* copy bandwidth of this machine
   (``roofline.copy_bandwidth_gbs``).  The Pallas column is only real on a
   TPU backend; on CPU it is ``None`` with a note (interpret mode measures
   the interpreter, not the kernel).
2. **GLM fused vs plain value_and_grad** — one `value_and_grad` of the
   fused potential (`infer={"potential": "glm"}` → one pass over X through
   `ops.glm_potential_grad` + O(d) custom-vjp backward) against the XLA
   forward+VJP pair of the plain potential, at n in {5k, 20k}, d=54.
3. **NUTS ms/leapfrog, plain vs glm-marked** — the end-to-end effect of
   (2) inside the jit'd executor on the CoverType-shaped logreg at
   n=20,000 (the acceptance shape).
4. **ChEES 64-chain warm wall** — the quick-mode configuration whose PR-5
   headline was ~5.7 s, now running the chain-batched megakernel
   trajectory (`velocity_verlet_batch`) instead of `vmap(halfstep)`.
"""
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import random

from benchmarks import roofline
from benchmarks.models import covtype_data, logreg_model, logreg_model_glm
from repro.kernels import ops


def _best_ms(fn, iters=30):
    """Best-of wall time of a blocking thunk, in ms (first call discarded:
    it may compile)."""
    fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _op_rows(on_tpu):
    """Per-op timings on hot-path shapes, ref path vs (TPU-only) Pallas."""
    C, D = 64, 4096
    n, d = 20_000, 54
    ks = random.split(random.PRNGKey(0), 6)
    z, r, g, noise = (random.normal(k, (C, D)) for k in ks[:4])
    m_inv = jnp.abs(random.normal(ks[4], (D,))) + 0.5
    x = random.normal(ks[5], (n, d))
    y = (random.uniform(random.PRNGKey(1), (n,)) < 0.5).astype(jnp.float32)
    w = random.normal(random.PRNGKey(2), (d,)) * 0.1
    f4 = 4  # f32 bytes

    eps = jnp.asarray(0.01)
    cases = [
        # read z/r/grad + m_inv, write z/r.  Operands are jit *arguments*,
        # never closed-over constants — a nullary jit constant-folds the
        # whole op away and times the result cache.
        ("leapfrog_halfstep_batch", f"C={C} D={D}",
         (5 * C * D + D) * f4,
         lambda zz, rr, gg, mm, ee: ops.leapfrog_halfstep_batch(
             zz, rr, gg, mm, ee),
         (z, r, g, m_inv, eps)),
        # read z/grad/noise + m_inv, write z'
        ("mala_step", f"C={C} D={D}", (4 * C * D + D) * f4,
         lambda zz, gg, nn, mm, ee: ops.mala_step(zz, gg, nn, mm, ee),
         (z, g, noise, m_inv, eps)),
        # read X (+ y), write nll + grad: one pass serves value AND grad
        ("glm_potential_grad", f"n={n} d={d}", (n * d + 2 * n + 2 * d) * f4,
         lambda xx, yy, ww: ops.glm_potential_grad(xx, yy, ww),
         (x, y, w)),
    ]
    rows = []
    for name, shape, nbytes, fn, operands in cases:
        jitted = jax.jit(fn)
        ref_ms = _best_ms(
            lambda: jax.block_until_ready(jitted(*operands)))
        pallas_ms = None
        if on_tpu:
            with ops.use_pallas(True):
                pjit = jax.jit(fn)
                pallas_ms = _best_ms(
                    lambda: jax.block_until_ready(pjit(*operands)))
        # roofline at THIS op's working-set size: a ~5 MB op runs out of
        # cache where the 64 MB streaming copy runs out of DRAM
        peak = roofline.copy_bandwidth_gbs(nbytes=max(nbytes // 2, 1 << 20))
        rows.append({"op": name, "shape": shape, "bytes_moved": nbytes,
                     "ref_ms": ref_ms, "pallas_ms": pallas_ms,
                     "peak_gbs": peak})
        print(f"  {name:26s} {shape:16s} ref {ref_ms:8.3f} ms"
              + (f"  pallas {pallas_ms:8.3f} ms" if pallas_ms is not None
                 else "  pallas —")
              + f"  (roofline {peak:.1f} GB/s at working-set size)",
              flush=True)
    return rows


def _glm_value_and_grad(sizes=(5_000, 20_000), d=54):
    """jit(value_and_grad(potential)) — plain XLA forward+VJP vs the fused
    single-pass potential, same model, same data, same probe point."""
    from repro.core.infer.util import initialize_model_structure

    rows = []
    for n in sizes:
        data = covtype_data(n=n, d=d)
        args, kw = (data["x"],), {"y": data["y"]}
        zp = random.normal(random.PRNGKey(3), (d,)) * 0.1
        out = {"n": n, "d": d}
        for label, model in (("plain", logreg_model),
                             ("fused", logreg_model_glm)):
            pot = initialize_model_structure(random.PRNGKey(0), model,
                                             args, kw)[0]
            vg = jax.jit(jax.value_and_grad(pot))
            out[f"{label}_ms"] = _best_ms(
                lambda: jax.block_until_ready(vg(zp)))
        out["speedup"] = out["plain_ms"] / max(out["fused_ms"], 1e-9)
        rows.append(out)
        print(f"  value_and_grad n={n:6d}: plain {out['plain_ms']:.3f} ms, "
              f"fused {out['fused_ms']:.3f} ms "
              f"({out['speedup']:.2f}x)", flush=True)
    return rows


def _nuts_glm(quick):
    """End-to-end ms/leapfrog of NUTS on the plain vs glm-marked logreg at
    the acceptance shape (n=20,000, d=54)."""
    from benchmarks.harness import run_nuts

    n = 20_000
    warm, samp = (100, 100) if quick else (200, 200)
    data = covtype_data(n=n)
    rows = {}
    for label, model in (("plain", logreg_model), ("glm", logreg_model_glm)):
        r = run_nuts(model, (data["x"],), {"y": data["y"]},
                     num_warmup=warm, num_samples=samp)
        rows[label] = r
        print(f"  nuts[{label:5s}] n={n}: {r['ms_per_leapfrog']:.4f} "
              f"ms/leapfrog (warm wall {r['warm_wall_s']:.2f}s, "
              f"min_ess {r['min_ess']:.0f})", flush=True)
    speedup = (rows["plain"]["ms_per_leapfrog"]
               / max(rows["glm"]["ms_per_leapfrog"], 1e-12))
    print(f"  glm-marked speedup: {speedup:.2f}x", flush=True)
    return {"n": n, "num_warmup": warm, "num_samples": samp,
            "plain": rows["plain"], "glm": rows["glm"],
            "ms_per_leapfrog_speedup": speedup}


def _chees_warm_wall():
    """The PR-5 quick headline configuration (64 chains, 150/150, logreg
    n=1000 d=16) — now on the megakernel trajectory path."""
    from benchmarks.chees import _run_one, covtype_like
    from repro.core.infer import ChEES

    data = covtype_like(n=1_000, d=16)
    r = _run_one(ChEES(logreg_model), 64, 150, 150, data)
    print(f"  chees 64 chains: warm wall {r['wall_s']:.2f}s "
          f"({r['samples_per_sec']:.0f} samples/s, "
          f"ESS/s {r['ess_per_sec']:.1f})", flush=True)
    return r


def main(quick=False):
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    peak_gbs = roofline.copy_bandwidth_gbs()
    print(f"  backend={backend}; measured copy roofline "
          f"{peak_gbs:.1f} GB/s", flush=True)

    op_rows = _op_rows(on_tpu)
    for r in op_rows:
        r["ref_roofline_fraction"] = roofline.kernel_fraction(
            r["bytes_moved"], r["ref_ms"] / 1e3, r["peak_gbs"])
        r["pallas_roofline_fraction"] = (
            roofline.kernel_fraction(r["bytes_moved"],
                                     r["pallas_ms"] / 1e3, r["peak_gbs"])
            if r["pallas_ms"] is not None else None)
    print(roofline.kernel_markdown(op_rows, peak_gbs), flush=True)

    glm_rows = _glm_value_and_grad()
    nuts_glm = _nuts_glm(quick)
    chees64 = _chees_warm_wall()

    rec = {
        "benchmark": "kernels_hotpath",
        "backend": backend,
        "copy_bandwidth_gbs": peak_gbs,
        "note": None if on_tpu else
        "pallas columns need a TPU backend; interpret mode measures the "
        "interpreter, not the kernel — ref-path numbers are the CPU truth",
        "ops": op_rows,
        "glm_value_and_grad": glm_rows,
        "nuts_glm": nuts_glm,
        "chees_64_chains": chees64,
    }
    print(json.dumps({k: v for k, v in rec.items() if k != "ops"},
                     indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
