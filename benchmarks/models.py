"""The paper's three benchmark models (Sec. 4 / App. C), in the repro API.

Datasets are synthesized to the paper's specs (offline container): the HMM
matches Stan manual §2.6 semi-supervised setup; logistic regression uses a
CoverType-shaped synthetic (581,012 x 54, binarized most-frequent class);
SKIM generates N=200 with 3 planted pairwise interactions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import random

import repro.core as pc
from repro.core import dist


# ---------------------------------------------------------------------------
# HMM (semi-supervised, 3 latent states, 10-dim categorical emissions)
# ---------------------------------------------------------------------------

def hmm_data(rng_key=None, T=600, T_sup=100, K=3, V=10):
    key = rng_key if rng_key is not None else random.PRNGKey(0)
    k1, k2, k3, k4 = random.split(key, 4)
    theta = dist.Dirichlet(jnp.full((K, K), 2.0)).sample(rng_key=k1)
    phi = dist.Dirichlet(jnp.full((K, V), 1.0)).sample(rng_key=k2)
    zs, ws = [jnp.zeros((), jnp.int32)], []
    key_seq = random.split(k3, T)
    key_emit = random.split(k4, T)
    z = jnp.zeros((), jnp.int32)
    for t in range(T):
        z = dist.Categorical(probs=theta[z]).sample(rng_key=key_seq[t])
        w = dist.Categorical(probs=phi[z]).sample(rng_key=key_emit[t])
        zs.append(z)
        ws.append(w)
    return {"w": jnp.stack(ws), "z_sup": jnp.stack(zs[1:T_sup + 1]),
            "T_sup": T_sup, "K": K, "V": V}


def hmm_model(data):
    K, V, T_sup = data["K"], data["V"], data["T_sup"]
    w = data["w"]
    theta = pc.sample("theta",
                      dist.Dirichlet(jnp.full((K, K), 2.0)).to_event(1))
    phi = pc.sample("phi", dist.Dirichlet(jnp.full((K, V), 1.0)).to_event(1))
    # supervised prefix: observed states
    z_sup = data["z_sup"]
    with pc.plate("sup", T_sup - 1):
        pc.sample("z_trans", dist.Categorical(probs=theta[z_sup[:-1]]),
                  obs=z_sup[1:])
        pc.sample("w_sup", dist.Categorical(probs=phi[z_sup[:-1]]),
                  obs=w[:T_sup - 1])
    # unsupervised suffix: marginalize latent states with a forward pass
    log_theta = jnp.log(theta)
    log_phi = jnp.log(phi)

    def step(log_alpha, wt):
        la = jax.nn.logsumexp(log_alpha[:, None] + log_theta, axis=0)
        la = la + log_phi[:, wt]
        return la, None

    init = log_theta[z_sup[-1]] + log_phi[:, w[T_sup - 1]]
    log_alpha, _ = jax.lax.scan(step, init, w[T_sup:])
    pc.sample("marginal", dist.Delta(jnp.zeros(()),
                                     log_density=jax.nn.logsumexp(log_alpha)),
              obs=jnp.zeros(()))


# ---------------------------------------------------------------------------
# fully-latent HMM (no supervision, no manual marginalization): the hidden
# states are summed out by the enumeration subsystem's `markov` combinator
# at O(T·K²) inside the jit'd NUTS potential (benchmarks/enum_hmm.py)
# ---------------------------------------------------------------------------

def enum_hmm_data(K, rng_key=None, T=120, V=16):
    key = rng_key if rng_key is not None else random.PRNGKey(0)
    k1, k2, k3 = random.split(key, 3)
    theta = dist.Dirichlet(jnp.full((K, K), 0.5)).sample(rng_key=k1)
    phi = dist.Dirichlet(jnp.full((K, V), 0.3)).sample(rng_key=k2)
    keys = random.split(k3, 2 * T)
    z, ws = jnp.zeros((), jnp.int32), []
    for t in range(T):
        z = dist.Categorical(probs=theta[z]).sample(rng_key=keys[2 * t])
        ws.append(dist.Categorical(probs=phi[z]).sample(rng_key=keys[2 * t + 1]))
    return {"w": jnp.stack(ws), "K": K, "V": V}


def enum_hmm_model(data):
    from repro.core.infer import markov
    K, V, w = data["K"], data["V"], data["w"]
    theta = pc.sample("theta",
                      dist.Dirichlet(jnp.full((K, K), 1.0)).to_event(1))
    phi = pc.sample("phi", dist.Dirichlet(jnp.full((K, V), 1.0)).to_event(1))

    def step(z_prev, w_t):
        z = pc.sample("z", dist.Categorical(probs=theta[z_prev]))
        pc.sample("w", dist.Categorical(probs=phi[z]), obs=w_t)
        return z

    markov(step, 0, w)


# ---------------------------------------------------------------------------
# logistic regression, CoverType-shaped (581012 x 54)
# ---------------------------------------------------------------------------

def covtype_data(rng_key=None, n=581_012, d=54):
    key = rng_key if rng_key is not None else random.PRNGKey(0)
    k1, k2, k3 = random.split(key, 3)
    x = random.normal(k1, (n, d))                 # features are normalized
    true_w = random.normal(k2, (d,)) * 0.5
    logits = x @ true_w
    y = dist.Bernoulli(logits=logits).sample(rng_key=k3)
    return {"x": x, "y": y.astype(jnp.float32)}


def logreg_model(x, y=None):
    d = x.shape[-1]
    w = pc.sample("w", dist.Normal(jnp.zeros(d), jnp.ones(d)).to_event(1))
    return pc.sample("y", dist.Bernoulli(logits=x @ w), obs=y)


def logreg_model_glm(x, y=None):
    """Same model, opted into the fused GLM potential: the likelihood value
    AND its gradient come from one ``ops.glm_potential_grad`` pass over x
    (verified affine at setup; falls back to the plain potential if not)."""
    d = x.shape[-1]
    w = pc.sample("w", dist.Normal(jnp.zeros(d), jnp.ones(d)).to_event(1))
    return pc.sample("y", dist.Bernoulli(logits=x @ w), obs=y,
                     infer={"potential": "glm"})


# ---------------------------------------------------------------------------
# SKIM — sparse kernel interaction model (Agrawal et al. 2019)
# ---------------------------------------------------------------------------

def skim_data(p, rng_key=None, n=200, n_inter=3):
    key = rng_key if rng_key is not None else random.PRNGKey(0)
    k1, k2, k3, k4 = random.split(key, 4)
    x = random.normal(k1, (n, p))
    pairs = random.choice(k2, p, shape=(n_inter, 2), replace=False)
    beta = jnp.zeros(p).at[pairs[:, 0]].set(1.0)
    inter = jnp.prod(x[:, pairs], axis=-1) @ jnp.ones(n_inter)
    y = x @ beta + 2.0 * inter + 0.1 * random.normal(k4, (n,))
    return {"x": x, "y": y}


def skim_model(x, y=None):
    """Kernel-trick formulation: per-dimension sparsity scales kappa with a
    horseshoe-like prior; interactions live in the quadratic kernel."""
    n, p = x.shape
    lam = pc.sample("lambda", dist.HalfCauchy(jnp.ones(p)).to_event(1))
    tau = pc.sample("tau", dist.HalfCauchy(1.0))
    eta1 = pc.sample("eta1", dist.HalfCauchy(1.0))
    c2 = pc.sample("c2", dist.InverseGamma(2.0, 2.0))
    sigma = pc.sample("sigma", dist.HalfNormal(1.0))
    lam2 = lam ** 2
    kappa = jnp.sqrt(eta1 ** 2 * c2 * lam2 / (eta1 ** 2 + c2 * lam2))
    xk = x * kappa * tau
    # quadratic kernel captures main + pairwise effects (kernel trick)
    k1 = xk @ xk.T
    K = (k1 + 1.0) ** 2 - 1.0
    K = K + (sigma ** 2 + 1e-4) * jnp.eye(n)
    pc.sample("y", dist.MultivariateNormal(jnp.zeros(n),
                                           covariance_matrix=K), obs=y)
