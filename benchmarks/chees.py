"""ChEES-HMC vs NUTS across chain counts: samples/sec and ESS/sec.

The ensemble claim, measured: NUTS pays per-chain ragged tree depth inside
the vmapped batch (every chain waits for the deepest tree) and adapts each
chain alone, while ChEES runs fixed-length lockstep trajectories with
cross-chain pooled warmup.  At 1 chain NUTS's adaptive trajectories win; as
the batch widens ChEES's flat iteration cost and sharper pooled adaptation
take over — warm ESS/sec at >= 8 chains is the acceptance metric.

Both kernels run through the identical jit'd chunked executor on the same
logreg posterior (CoverType-shaped: heterogeneous column scales + AR(0.5)
correlation, see ``covtype_like``); ESS is the minimum over coefficients
(the conservative whole-vector rate), measured on the warm (cache-hit) run
like multichain.py.
"""
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import random

from benchmarks.models import logreg_model
from repro.core.infer import ChEES, MCMC, NUTS, effective_sample_size


def covtype_like(n, d, seed=0):
    """CoverType-*shaped* design: heterogeneous column scales (log-uniform
    over two decades, like elevation-in-meters next to binary indicators)
    plus AR(0.5) column correlation.  The iid-normal synthetic in
    ``models.covtype_data`` yields an almost perfectly isotropic posterior —
    a geometry real tabular data never has and on which NUTS's antithetic
    draws are unrealistically flattering; this one forces the deeper, ragged
    trees the real dataset does."""
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, d)).astype(np.float32)
    corr = np.linalg.cholesky(
        0.5 ** np.abs(np.subtract.outer(np.arange(d), np.arange(d))))
    scales = np.exp(rng.uniform(np.log(0.1), np.log(10.0),
                                size=d)).astype(np.float32)
    x = (z @ corr.T.astype(np.float32)) * scales
    true_w = (rng.normal(size=d) * 0.5 / scales).astype(np.float32)
    p = 1.0 / (1.0 + np.exp(-(x @ true_w)))
    y = (rng.random(n) < p).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def _run_one(kernel, chains, warm, samp, data):
    mcmc = MCMC(kernel, num_warmup=warm, num_samples=samp,
                num_chains=chains, chain_method="vectorized")
    t0 = time.time()
    mcmc.run(random.PRNGKey(0), data["x"], y=data["y"])
    jax.block_until_ready(mcmc.get_samples())
    cold = time.time() - t0
    t1 = time.time()
    mcmc.run(random.PRNGKey(1), data["x"], y=data["y"])
    jax.block_until_ready(mcmc.get_samples())
    wall = time.time() - t1
    w = np.asarray(mcmc.get_samples(group_by_chain=True)["w"])
    ess = float(min(effective_sample_size(w[..., i])
                    for i in range(w.shape[-1])))
    return {"chains": chains,
            "samples_per_sec": chains * samp / wall,
            "min_ess": ess,
            "ess_per_sec": ess / wall,
            "wall_s": wall,
            "compile_s": cold - wall}


def main(quick=False):
    n, d = (1_000, 16) if quick else (2_000, 54)
    data = covtype_like(n=n, d=d)
    warm, samp = (150, 150) if quick else (300, 300)
    sweep = (1, 8, 64)
    rows = []
    for chains in sweep:
        for name, kernel in (("nuts", NUTS(logreg_model)),
                             ("chees", ChEES(logreg_model))):
            r = _run_one(kernel, chains, warm, samp, data)
            r["kernel"] = name
            rows.append(r)
            print(f"  {name:5s} chains={chains:3d}  "
                  f"{r['samples_per_sec']:9.1f} samples/s  "
                  f"{r['ess_per_sec']:9.1f} ESS/s  "
                  f"(warm wall {r['wall_s']:.2f}s, compile "
                  f"{r['compile_s']:.1f}s)", flush=True)
    # headline: ESS/sec ratio chees/nuts at the widest batch
    widest = sweep[-1]
    by = {r["kernel"]: r for r in rows if r["chains"] == widest}
    ratio = by["chees"]["ess_per_sec"] / max(by["nuts"]["ess_per_sec"], 1e-9)
    print(f"  ESS/sec at {widest} chains: chees/nuts = {ratio:.2f}x")
    rec = {"benchmark": "chees_vs_nuts",
           "model": f"logreg n={n} d={d}", "num_warmup": warm,
           "num_samples": samp, "rows": rows,
           "ess_per_sec_ratio_at_max_chains": ratio}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
