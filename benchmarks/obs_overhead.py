"""Telemetry overhead on the logreg quick headline (docs/observability.md).

Warm-path walls over the same compiled-program shapes — telemetry detached
vs. a full :class:`repro.obs.Telemetry` (metrics stream, spans, JSONL
events, manifest) — on the quick Table-2a settings (CoverType-shaped
n=20k, 150 warmup + 150 samples, 4 chains).  The acceptance bar is
``overhead_pct < 3``: metrics ride the chunk scan's collect outputs and
drain once per compiled chunk, so the only added work is one device→host
transfer per chunk plus host-side JSON appends.  A third arm adds the
convergence gate (``until=Converged(...)`` with unreachable thresholds so
the run keeps its full length) and holds ``monitor_overhead_pct`` to the
same 3% budget — the streaming R-hat/ESS folds reuse the chunk drain, so
gating costs chunked programs plus host numpy, never extra syncs.

Measurement protocol: both arms run the *same* rng key (bit-identity makes
the device work identical draw for draw), reps are interleaved off/on to
decorrelate machine noise, and the headline compares min-walls — on a
shared CPU the per-rep spread (~±5%) is larger than the effect being
measured, so means would report noise.  Every timed run blocks on the
collected samples: without telemetry the executor dispatches
asynchronously, and an unblocked wall measures dispatch, not work.
"""
import json
import shutil
import sys
import tempfile
import time

from jax import random

from benchmarks.models import covtype_data, logreg_model


def _make(telemetry, data, num_chains=4, until=None):
    """Build + compile (one throwaway run) an MCMC for one arm."""
    import jax

    from repro.core.infer import MCMC, NUTS

    mcmc = MCMC(NUTS(logreg_model), num_warmup=150, num_samples=150,
                num_chains=num_chains, progress=False, telemetry=telemetry)
    mcmc.run(random.PRNGKey(0), data["x"], y=data["y"], until=until)
    jax.block_until_ready(mcmc.get_samples())
    return mcmc


def main(quick=False):
    import jax

    from repro import obs

    data = covtype_data(n=20_000)
    out_dir = tempfile.mkdtemp(prefix="obs_overhead_")
    # ~±5% per-rep machine noise vs a <3% budget: even quick mode needs
    # enough reps for the min-wall to converge
    reps = 6
    # the monitor arm adds the convergence gate on top of full telemetry:
    # streaming R-hat/ESS folds + gate checks at every check_every-sized
    # chunk boundary.  The thresholds are valid (RPL403-clean) but jointly
    # unreachable — split R-hat can dip below 1 by chance, so max_rhat
    # alone is not enough; requiring ESS at the full nominal budget too
    # keeps the run at full length and the walls comparable
    until = obs.Converged(max_rhat=1.0 + 1e-9, min_ess=150.0 * 4,
                          check_every=50, batch_size=10)
    try:
        arms = [("off", _make(None, data), None),
                ("on", _make(obs.Telemetry(dir=out_dir), data), None),
                ("monitor", _make(obs.Telemetry(dir=out_dir), data,
                                  until=until), until)]
        walls = {name: [] for name, _, _ in arms}
        for _ in range(reps):
            for name, mcmc, arm_until in arms:
                t0 = time.time()
                mcmc.run(random.PRNGKey(1), data["x"], y=data["y"],
                         until=arm_until)
                jax.block_until_ready(mcmc.get_samples())
                walls[name].append(time.time() - t0)
    finally:
        shutil.rmtree(out_dir, ignore_errors=True)
    off_s, on_s = min(walls["off"]), min(walls["on"])
    mon_s = min(walls["monitor"])
    overhead_pct = 100.0 * (on_s - off_s) / off_s
    monitor_overhead_pct = 100.0 * (mon_s - off_s) / off_s
    rec = {"benchmark": "obs_overhead_logreg_quick", "n": 20_000,
           "num_warmup": 150, "num_samples": 150, "num_chains": 4,
           "reps": reps, "warm_wall_off_s": off_s, "warm_wall_on_s": on_s,
           "warm_wall_monitor_s": mon_s,
           "walls_off_s": walls["off"], "walls_on_s": walls["on"],
           "walls_monitor_s": walls["monitor"],
           "overhead_pct": overhead_pct, "budget_pct": 3.0,
           "within_budget": bool(overhead_pct < 3.0),
           "monitor_overhead_pct": monitor_overhead_pct,
           "monitor_within_budget": bool(monitor_overhead_pct < 3.0)}
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
